//! Bench: crash faults, checkpoint/restore and the tunnel retry
//! ladder at fleet scale (DESIGN.md §Crash-Recovery).
//!
//! Three sections, guarded then measured:
//!
//! 1. **Off-identity guard** — a trace whose checkpoint interval can
//!    never be reached and whose link-fault probability is effectively
//!    zero must be bit-identical to the all-defaults-off run. Asserted
//!    before anything is recorded.
//! 2. **Checkpoint interval vs goodput** — the same crash schedule
//!    replayed under a sweep of checkpoint cadences: tight intervals
//!    pay steady-state checkpoint I/O to lose almost nothing per
//!    crash; loose intervals run lean and redo big tails. Measures
//!    lost steps, checkpoint bytes and completed-jobs-per-hour per
//!    interval.
//! 3. **Retry-ladder overhead** — the crash-free trace with a lossy
//!    tunnel (5% per-attempt failure, 9-rung ladder): every loss
//!    retries with exponential backoff and none escalates, pricing the
//!    ladder's makespan stretch against the faultless baseline.
//!
//! Emits machine-readable numbers to `BENCH_8.json` (section
//! `"crash"`).
//!
//! Run: `cargo bench --bench crash`

// Benches are wall-clock consumers by definition; the crate-wide
// clippy gate on time sources is lifted per bench target.
#![allow(clippy::disallowed_methods)]

use std::time::Instant;

use stannis::config::{
    CheckpointSpec, CrashSpec, ExperimentConfig, LinkFaultSpec, WeightedJob, WorkloadSpec,
};
use stannis::fleet::run_trace;
use stannis::metrics::{f, print_table, record_bench_json_to};

const POOL: usize = 24;
const JOBS: usize = 400;

/// Host-free, small-dataset mix (same shape as the endurance bench):
/// the trace exercises admission churn and ring traffic, not one
/// shared bottleneck.
fn lean_mix() -> Vec<WeightedJob> {
    vec![
        WeightedJob {
            weight: 3.0,
            job: ExperimentConfig {
                network: "mobilenet_v2".into(),
                num_csds: 3,
                include_host: false,
                steps: 20,
                public_images: 384,
                private_per_csd: 64,
                ..Default::default()
            },
        },
        WeightedJob {
            weight: 1.0,
            job: ExperimentConfig {
                network: "squeezenet".into(),
                num_csds: 2,
                include_host: false,
                steps: 15,
                public_images: 256,
                private_per_csd: 64,
                ..Default::default()
            },
        },
    ]
}

fn base_spec() -> WorkloadSpec {
    WorkloadSpec {
        total_csds: POOL,
        stage_io: false,
        jobs: JOBS,
        mean_interarrival_secs: 12.0,
        seed: 23,
        mix: lean_mix(),
        ..Default::default()
    }
}

/// A dozen bay crashes spread across the trace's arrival window.
fn crash_schedule() -> Vec<CrashSpec> {
    (0..12)
        .map(|i| CrashSpec { device: (i * 5) % POOL, at_secs: 200.0 + 350.0 * i as f64 })
        .collect()
}

fn main() {
    // --- Guard: unreachable knobs must be invisible, to the bit -----------
    let base = base_spec();
    let mut armed = base.clone();
    armed.checkpoint = CheckpointSpec { interval_steps: 1 << 40, host_copy: true };
    armed.link_fault = LinkFaultSpec { fail_prob: 1e-300, ..Default::default() };
    let off = run_trace(&base).expect("crash-pipeline-off guard trace");
    let on = run_trace(&armed).expect("unreachable-knobs guard trace");
    assert_eq!(
        off, on,
        "unreachable checkpoint/link-fault knobs must leave the trace \
         bit-identical to the crash pipeline off"
    );
    assert_eq!(on.crashed, 0);
    assert_eq!(on.lost_steps, 0);
    assert_eq!(on.checkpoint_bytes, 0);
    assert_eq!(on.link_retries, 0);
    assert_eq!(on.devices_replaced, 0);

    // --- Checkpoint interval vs goodput under a fixed crash schedule ------
    let intervals: &[u64] = &[0, 2, 5, 10, 25];
    let mut rows = Vec::new();
    let mut recorded: Vec<(String, f64)> = Vec::new();
    for &interval in intervals {
        let mut spec = base_spec();
        spec.crashes = crash_schedule();
        spec.checkpoint = CheckpointSpec { interval_steps: interval, host_copy: false };
        let t0 = Instant::now();
        let s = run_trace(&spec).expect("crash-schedule trace");
        let wall = t0.elapsed().as_secs_f64();
        // Crash conservation at trace scale: every crash retires one
        // cancelled victim and submits one successor, so every original
        // arrival still completes.
        assert_eq!(s.completed, JOBS, "interval {interval}: arrivals must all complete");
        assert_eq!(s.cancelled, s.crashed, "interval {interval}: only crashes cancel here");
        assert_eq!(s.devices_replaced, 12, "every scheduled crash swaps one module");
        let hours = s.makespan.as_secs_f64() / 3600.0;
        let jobs_per_hour = s.completed as f64 / hours.max(1e-12);
        let ckpt_mb = s.checkpoint_bytes as f64 / 1e6;
        rows.push(vec![
            if interval == 0 { "off".into() } else { interval.to_string() },
            s.crashed.to_string(),
            s.lost_steps.to_string(),
            f(ckpt_mb, 1),
            f(hours, 2),
            f(jobs_per_hour, 1),
            format!("{wall:.2} s"),
        ]);
        let tag = if interval == 0 { "off".to_string() } else { interval.to_string() };
        recorded.push((format!("ck_{tag}_crashed"), s.crashed as f64));
        recorded.push((format!("ck_{tag}_lost_steps"), s.lost_steps as f64));
        recorded.push((format!("ck_{tag}_checkpoint_mb"), ckpt_mb));
        recorded.push((format!("ck_{tag}_makespan_h"), hours));
        recorded.push((format!("ck_{tag}_jobs_per_hour"), jobs_per_hour));
    }
    print_table(
        &format!("Checkpoint interval vs goodput — {JOBS} arrivals, 12 scheduled crashes"),
        &["interval", "crashed", "lost steps", "ckpt MB", "makespan h", "jobs/h", "wall"],
        &rows,
    );

    // --- Retry-ladder overhead on a lossy (but never fatal) tunnel --------
    let mut lossy = base_spec();
    lossy.link_fault =
        LinkFaultSpec { fail_prob: 0.05, max_retries: 8, ..Default::default() };
    let t0 = Instant::now();
    let faultless = run_trace(&base).expect("faultless baseline trace");
    let base_wall = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let retried = run_trace(&lossy).expect("lossy-tunnel trace");
    let lossy_wall = t0.elapsed().as_secs_f64();
    assert_eq!(retried.crashed, 0, "a 9-rung ladder must never exhaust at 5% loss");
    assert!(retried.link_retries > 0, "a 5% loss rate must exercise the ladder");
    assert_eq!(retried.completed, JOBS);
    let stretch =
        retried.makespan.as_secs_f64() / faultless.makespan.as_secs_f64().max(1e-12);
    println!(
        "retry ladder: {} retries, makespan x{:.4} vs faultless ({:.2}s vs {:.2}s wall)",
        retried.link_retries, stretch, lossy_wall, base_wall,
    );

    let mut pairs: Vec<(&str, f64)> = vec![
        ("jobs", JOBS as f64),
        ("scheduled_crashes", 12.0),
        ("retry_link_retries", retried.link_retries as f64),
        ("retry_makespan_stretch", stretch),
    ];
    pairs.extend(recorded.iter().map(|(k, v)| (k.as_str(), *v)));
    record_bench_json_to("BENCH_8.json", "crash", &pairs);
}
