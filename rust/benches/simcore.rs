//! Bench: the simulation core's own cost — slab event-queue throughput,
//! interned vs string-keyed perfmodel lookups, the memoized Algorithm-1
//! sweep, and a fig7-shaped sweep at production step counts (which the
//! scheduler's steady-state fast-forward collapses to closed form).
//! The fleet-level fast-forward-vs-per-step comparison lives in
//! `rust/benches/fleet.rs` — one owner for that harness.
//!
//! Emits machine-readable numbers to `BENCH_2.json` (section
//! `"simcore"`) so the perf trajectory is tracked across PRs.
//!
//! Run: `cargo bench --bench simcore`

// Benches are wall-clock consumers by definition; the crate-wide
// clippy gate on time sources is lifted per bench target.
#![allow(clippy::disallowed_methods)]

use std::time::Instant;

use stannis::coordinator::{modeled_throughput, tune, TuneConfig};
use stannis::metrics::{bench, record_bench_json};
use stannis::perfmodel::{Device, NetId, PerfModel};
use stannis::sim::{EventQueue, SimTime};
use stannis::util::Rng;

const QUEUE_EVENTS: u64 = 200_000;
const MODEL_CALLS: u64 = 200_000;

fn queue_churn(events: u64, cancel_every: u64) -> u64 {
    let mut rng = Rng::new(0x51AB);
    let mut q = EventQueue::new();
    let mut ops = 0u64;
    let mut ids = Vec::new();
    for i in 0..events {
        ids.push(q.schedule(SimTime::ns(rng.below(1 << 40)), i));
        ops += 1;
        if cancel_every > 0 && i % cancel_every == cancel_every - 1 {
            let pick = ids.swap_remove(rng.usize_below(ids.len()));
            if q.cancel(pick) {
                ops += 1;
            }
        }
    }
    while q.pop().is_some() {
        ops += 1;
    }
    ops
}

fn main() {
    let mut ledger: Vec<(&str, f64)> = Vec::new();

    // --- Event queue ------------------------------------------------------
    let r = bench("event_queue schedule+pop (200k)", 1, 10, || {
        std::hint::black_box(queue_churn(QUEUE_EVENTS, 0));
    });
    println!("{}", r.summary());
    ledger.push(("event_queue_events_per_sec", 2.0 * QUEUE_EVENTS as f64 / r.mean_secs()));

    // The op count is deterministic: capture it from the warmup-shaped
    // pre-run instead of re-churning after the timed loop.
    let cancel_ops = queue_churn(QUEUE_EVENTS, 2) as f64;
    let r = bench("event_queue with 1-in-2 cancels", 0, 10, || {
        std::hint::black_box(queue_churn(QUEUE_EVENTS, 2));
    });
    println!("{}", r.summary());
    ledger.push(("event_queue_cancel_heavy_ops_per_sec", cancel_ops / r.mean_secs()));

    let r = bench("event_queue drain_until (batched)", 1, 10, || {
        let mut q = EventQueue::new();
        for i in 0..QUEUE_EVENTS {
            q.schedule(SimTime::ns(i * 7 % (1 << 20)), i);
        }
        let mut n = 0u64;
        for e in q.drain_until(SimTime::ns(1 << 20)) {
            n += e.payload & 1;
        }
        std::hint::black_box(n);
    });
    println!("{}", r.summary());
    ledger.push(("drain_until_events_per_sec", 2.0 * QUEUE_EVENTS as f64 / r.mean_secs()));

    // --- Perf model: string shim vs interned id ---------------------------
    let model = PerfModel::default();
    let net = NetId::resolve("mobilenet_v2").unwrap();
    let r_str = bench("step_time via string resolve", 1, 10, || {
        let mut acc = SimTime::ZERO;
        for i in 0..MODEL_CALLS {
            acc += model
                .step_time(Device::NewportIsp, "mobilenet_v2_s", 1 + (i % 64) as usize)
                .unwrap();
        }
        std::hint::black_box(acc);
    });
    println!("{}", r_str.summary());
    let r_id = bench("step_time via interned NetId", 1, 10, || {
        let mut acc = SimTime::ZERO;
        for i in 0..MODEL_CALLS {
            acc += model
                .step_time_id(Device::NewportIsp, net, 1 + (i % 64) as usize)
                .unwrap();
        }
        std::hint::black_box(acc);
    });
    println!("{}", r_id.summary());
    ledger.push(("step_time_string_ns", r_str.mean_ns / MODEL_CALLS as f64));
    ledger.push(("step_time_interned_ns", r_id.mean_ns / MODEL_CALLS as f64));

    let r = bench("tune() full Algorithm-1 sweep", 2, 20, || {
        let mut m = PerfModel::default();
        for n in ["mobilenet_v2", "nasnet", "inception_v3", "squeezenet"] {
            std::hint::black_box(tune(&mut m, n, &TuneConfig::default()).unwrap());
        }
    });
    println!("{}", r.summary());
    ledger.push(("tune_four_nets_ns", r.mean_ns));

    // --- Fig. 7-shaped sweep at production step counts --------------------
    // Each datapoint is a 10k-step modeled run; the scheduler's
    // fast-forward makes this closed-form per point.
    let t0 = Instant::now();
    let mut checksum = 0.0f64;
    for net in ["mobilenet_v2", "nasnet", "inception_v3", "squeezenet"] {
        for n in [0usize, 4, 12, 24] {
            checksum += modeled_throughput(net, n, true, 25, 315, 10_000)
                .unwrap()
                .images_per_sec;
        }
    }
    std::hint::black_box(checksum);
    let sweep_wall = t0.elapsed().as_secs_f64();
    println!("\nfig7-shaped sweep @10k steps: {:.3} ms", sweep_wall * 1e3);
    ledger.push(("fig7_sweep_10k_steps_wall_s", sweep_wall));

    record_bench_json("simcore", &ledger);
}
