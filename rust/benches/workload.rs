//! Bench: the online fleet runtime under open-loop traffic — a seeded
//! Poisson arrival sweep (sustained jobs/hour, p50/p99 queue wait) and
//! a cancel-heavy churn run, on a 24-bay chassis. Before recording
//! anything the bench asserts that slicing the session into
//! per-external-event `run_until` calls is bit-identical to draining it
//! in one shot (the §Runtime window-boundary rule).
//!
//! Emits machine-readable numbers to `BENCH_5.json` (section
//! `"workload"`).
//!
//! Run: `cargo bench --bench workload`

// Benches are wall-clock consumers by definition; the crate-wide
// clippy gate on time sources is lifted per bench target.
#![allow(clippy::disallowed_methods)]

use std::time::Instant;

use stannis::config::{CancelSpec, WorkloadSpec};
use stannis::fleet::{run_trace_with, runtime_for, FleetReport, FleetRuntime, RuntimeEvent};
use stannis::metrics::{f, percentile, print_table, record_bench_json_to};

const POOL: usize = 24;

fn runtime(spec: &WorkloadSpec) -> FleetRuntime {
    runtime_for(spec)
}

/// One-shot run: load the trace, drain to idle. Returns the drained
/// session (for report + ledgers) and the wall time.
fn run_trace(spec: &WorkloadSpec) -> (FleetRuntime, f64) {
    let mut rt = runtime(spec);
    rt.load_workload(spec).expect("load workload trace");
    let t0 = Instant::now();
    rt.run_until_idle().expect("workload run");
    let wall = t0.elapsed().as_secs_f64();
    (rt, wall)
}

/// Sliced run: `run_until` at every external boundary, then idle.
fn run_trace_sliced(spec: &WorkloadSpec) -> FleetReport {
    let mut rt = runtime(spec);
    let boundaries = rt.load_workload(spec).expect("load workload trace");
    for t in boundaries {
        rt.run_until(t).expect("workload slice");
    }
    rt.run_until_idle().expect("workload run");
    rt.report()
}

fn main() {
    // --- Guard: sliced driving must be bit-identical to one-shot ----------
    let guard_spec = WorkloadSpec {
        total_csds: POOL,
        stage_io: false,
        jobs: 12,
        mean_interarrival_secs: 20.0,
        cancels: vec![CancelSpec { job: 2, at_secs: 90.0 }],
        faults: vec![
            stannis::config::FaultSpec { at_secs: 45.0, device: 0, factor: 0.6 },
            stannis::config::FaultSpec { at_secs: 150.0, device: 0, factor: 2.0 },
        ],
        ..Default::default()
    };
    let (one_rt, _) = run_trace(&guard_spec);
    let one = one_rt.report();
    let sliced = run_trace_sliced(&guard_spec);
    assert_eq!(one.makespan, sliced.makespan, "slicing must not change the timeline");
    assert_eq!(one.total_images, sliced.total_images);
    assert_eq!(one.link_bytes, sliced.link_bytes);
    assert_eq!(
        one.total_energy_j.to_bits(),
        sliced.total_energy_j.to_bits(),
        "slicing must be energy-bit-identical"
    );

    // --- Poisson arrival sweep --------------------------------------------
    const SWEEP_JOBS: usize = 48;
    let mut rows = Vec::new();
    let mut sweep_wall = 0.0;
    let mut heavy = None;
    for mean_gap in [120.0f64, 60.0, 30.0, 10.0] {
        let spec = WorkloadSpec {
            total_csds: POOL,
            stage_io: false,
            jobs: SWEEP_JOBS,
            mean_interarrival_secs: mean_gap,
            seed: 11,
            ..Default::default()
        };
        // Streaming run: per-job waits come off the retired-record
        // stream — the runtime keeps no terminal jobs.
        let mut waits: Vec<f64> = Vec::new();
        let t0 = Instant::now();
        let (summary, _rt) = run_trace_with(&spec, |e| {
            if let RuntimeEvent::Retired { record } = &e.event {
                waits.push(record.report.queue_wait.as_secs_f64());
            }
        })
        .expect("workload sweep trace");
        let wall = t0.elapsed().as_secs_f64();
        sweep_wall += wall;
        waits.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let hours = summary.makespan.as_secs_f64() / 3600.0;
        let jobs_per_hour = summary.jobs as f64 / hours.max(1e-12);
        let (p50, p99) = (percentile(&waits, 0.50), percentile(&waits, 0.99));
        rows.push(vec![
            f(mean_gap, 0),
            summary.jobs.to_string(),
            summary.makespan.to_string(),
            f(jobs_per_hour, 1),
            f(p50, 1),
            f(p99, 1),
            f(summary.aggregate_ips, 1),
            format!("{:.3} ms", wall * 1e3),
        ]);
        heavy = Some((jobs_per_hour, p50, p99)); // densest point wins (last)
    }
    print_table(
        &format!("Workload sweep — {SWEEP_JOBS} Poisson arrivals on a {POOL}-bay chassis"),
        &["mean gap s", "jobs", "makespan", "jobs/h", "wait p50 s", "wait p99 s", "agg img/s", "wall"],
        &rows,
    );
    let (jobs_per_hour, p50, p99) = heavy.expect("sweep ran");

    // --- Cancel-heavy churn -----------------------------------------------
    // Half the arrivals are torn down mid-flight: admission, layout,
    // teardown and backfill all churn continuously.
    const CHURN_JOBS: usize = 40;
    let churn = WorkloadSpec {
        total_csds: POOL,
        stage_io: false,
        jobs: CHURN_JOBS,
        mean_interarrival_secs: 10.0,
        seed: 13,
        cancels: (0..CHURN_JOBS)
            .step_by(2)
            .map(|i| CancelSpec { job: i, at_secs: 12.0 + 9.0 * i as f64 })
            .collect(),
        ..Default::default()
    };
    let (churn_rt, churn_wall) = run_trace(&churn);
    let cr = churn_rt.report();
    let freed = churn_rt.data_plane().stats().freed_pages;
    let cancels = churn_rt.data_plane().stats().cancels;
    println!(
        "\nchurn: {} arrivals, {} cancelled ({} teardown(s), {} page(s) freed), makespan {}, wall {:.3} ms",
        CHURN_JOBS,
        cr.cancelled,
        cancels,
        freed,
        cr.makespan,
        churn_wall * 1e3,
    );
    assert!(cr.cancelled > 0, "churn must actually cancel jobs");

    record_bench_json_to(
        "BENCH_5.json",
        "workload",
        &[
            ("sweep_jobs", SWEEP_JOBS as f64),
            ("jobs_per_hour_sustained", jobs_per_hour),
            ("queue_wait_p50_s", p50),
            ("queue_wait_p99_s", p99),
            ("arrival_sweep_wall_s", sweep_wall),
            ("churn_wall_s", churn_wall),
            ("churn_cancelled_jobs", cr.cancelled as f64),
            ("churn_freed_pages", freed as f64),
        ],
    );
}
