//! Bench: the extent-based storage stack (DESIGN.md §Perf, "Extent
//! I/O") — bulk FTL write/read runs vs the per-page reference loops
//! (asserting bit-identical outcomes *before* recording any number),
//! indexed vs full-scan GC victim selection under overwrite pressure,
//! a ~100k-image admission layout through the data plane, and a
//! degraded-fleet rebalance window.
//!
//! Emits machine-readable numbers to `BENCH_4.json` (section
//! `"storage"`).
//!
//! Run: `cargo bench --bench storage`

// Benches are wall-clock consumers by definition; the crate-wide
// clippy gate on time sources is lifted per bench target.
#![allow(clippy::disallowed_methods)]

use std::time::Instant;

use stannis::coordinator::{balance, balance_weighted};
use stannis::csd::{CsdConfig, FlashConfig, Ftl, FtlConfig};
use stannis::data::{Dataset, DatasetConfig};
use stannis::fleet::{DataPlane, DevicePool, JobId};
use stannis::metrics::{bench, f, record_bench_json_to};
use stannis::sim::SimTime;
use stannis::tunnel::{Tunnel, TunnelConfig};

const BENCH_JSON: &str = "BENCH_4.json";

/// Mid-sized FTL: big enough that GC victim scans hurt, small enough
/// that an iteration stays in the millisecond range.
fn bench_ftl() -> Ftl {
    let cfg = FtlConfig {
        flash: FlashConfig {
            channels: 8,
            dies_per_channel: 2,
            blocks_per_die: 64,
            pages_per_block: 32,
            page_bytes: 4096,
            ..Default::default()
        },
        overprovision: 0.125,
        gc_low_water: 8,
        gc_high_water: 16,
        ..Default::default()
    };
    Ftl::new(cfg, 42)
}

const RUN: u32 = 32;

/// Write every logical page once (sequential runs), then overwrite a
/// skewed third — enough churn to keep GC busy.
fn write_workload_bulk(ftl: &mut Ftl) -> (u64, SimTime) {
    let n = ftl.logical_pages() as u32;
    let mut pages = 0u64;
    let mut last = SimTime::ZERO;
    let mut lpn = 0u32;
    while lpn < n {
        let len = RUN.min(n - lpn);
        last = last.max(ftl.write_fill(lpn, len, lpn as u64, SimTime::ZERO).unwrap());
        pages += len as u64;
        lpn += len;
    }
    let mut lpn = 0u32;
    while lpn + RUN <= n {
        last = last.max(ftl.write_fill(lpn, RUN, !lpn as u64, SimTime::ZERO).unwrap());
        pages += RUN as u64;
        lpn += 3 * RUN;
    }
    (pages, last)
}

/// The per-page reference: the identical workload through `write`.
fn write_workload_per_page(ftl: &mut Ftl) -> (u64, SimTime) {
    let n = ftl.logical_pages() as u32;
    let mut pages = 0u64;
    let mut last = SimTime::ZERO;
    let mut lpn = 0u32;
    while lpn < n {
        let len = RUN.min(n - lpn);
        for k in 0..len {
            last = last.max(ftl.write(lpn + k, lpn as u64, SimTime::ZERO).unwrap());
        }
        pages += len as u64;
        lpn += len;
    }
    let mut lpn = 0u32;
    while lpn + RUN <= n {
        for k in 0..RUN {
            last = last.max(ftl.write(lpn + k, !lpn as u64, SimTime::ZERO).unwrap());
        }
        pages += RUN as u64;
        lpn += 3 * RUN;
    }
    (pages, last)
}

fn main() {
    // --- Bulk vs per-page equality gate -----------------------------------
    // Two identically-seeded FTLs run the same workload through the
    // extent path and the per-page reference; every observable must be
    // bit-identical before any throughput number is recorded.
    let mut bulk = bench_ftl();
    let mut refr = bench_ftl();
    let (wp, bulk_last) = write_workload_bulk(&mut bulk);
    let (wp_ref, ref_last) = write_workload_per_page(&mut refr);
    assert_eq!(wp, wp_ref);
    assert_eq!(bulk_last, ref_last, "bulk write completion must equal per-page");
    assert_eq!(bulk.stats(), refr.stats(), "FtlStats must be bit-identical");
    assert_eq!(bulk.flash_stats(), refr.flash_stats());
    assert_eq!(bulk.free_block_count(), refr.free_block_count());
    bulk.check_invariants().unwrap();
    refr.check_invariants().unwrap();
    let n = bulk.logical_pages() as u32;
    let mut lpn = 0u32;
    let mut rd_bulk = SimTime::ZERO;
    let mut rd_ref = SimTime::ZERO;
    while lpn < n {
        let len = RUN.min(n - lpn);
        rd_bulk = rd_bulk.max(bulk.read_run(lpn, len, SimTime::ZERO).unwrap());
        for k in 0..len {
            rd_ref = rd_ref.max(refr.read(lpn + k, SimTime::ZERO).unwrap().done);
        }
        lpn += len;
    }
    assert_eq!(rd_bulk, rd_ref, "bulk read completion must equal per-page");
    assert_eq!(bulk.stats(), refr.stats());
    println!(
        "equality gate: {wp} pages written + {n} read, bulk == per-page (WAF {})",
        f(bulk.stats().waf(), 3)
    );
    assert_eq!(bulk.gc_victim(), bulk.gc_victim_scan(), "victim index == full scan");

    // --- FTL write/read throughput ----------------------------------------
    let wr_bulk = bench("ftl write_run (GC churn)", 1, 8, || {
        let mut ftl = bench_ftl();
        std::hint::black_box(write_workload_bulk(&mut ftl));
    });
    let wr_page = bench("ftl write per-page (GC churn)", 1, 8, || {
        let mut ftl = bench_ftl();
        std::hint::black_box(write_workload_per_page(&mut ftl));
    });
    let write_run_pps = wp as f64 / wr_bulk.mean_secs();
    let write_page_pps = wp as f64 / wr_page.mean_secs();
    println!("{}", wr_bulk.summary());
    println!("{}", wr_page.summary());
    println!(
        "write path: {} pages/s bulk vs {} pages/s per-page ({}x)",
        f(write_run_pps, 0),
        f(write_page_pps, 0),
        f(write_run_pps / write_page_pps, 2)
    );
    let mut reader = bench_ftl();
    write_workload_bulk(&mut reader);
    let rd = bench("ftl read_run (full sweep)", 1, 8, || {
        let mut lpn = 0u32;
        while lpn < n {
            let len = RUN.min(n - lpn);
            std::hint::black_box(reader.read_run(lpn, len, SimTime::ZERO).unwrap());
            lpn += len;
        }
    });
    let read_run_pps = n as f64 / rd.mean_secs();
    println!("{}", rd.summary());

    // --- GC victim selection: index vs full scan --------------------------
    // `bulk` is left in a post-churn state with plenty of partially
    // invalid blocks — selection pressure is realistic.
    assert_eq!(bulk.gc_victim(), bulk.gc_victim_scan());
    let idx = bench("gc victim (incremental index)", 10, 400, || {
        std::hint::black_box(bulk.gc_victim());
    });
    let scan = bench("gc victim (full scan)", 10, 400, || {
        std::hint::black_box(bulk.gc_victim_scan());
    });
    let victim_speedup = scan.mean_ns / idx.mean_ns;
    println!("{}", idx.summary());
    println!("{}", scan.summary());
    println!("victim selection speedup: {}x", f(victim_speedup, 1));

    // --- Admission layout: ~100k images through the data plane ------------
    let csd_cfg = CsdConfig {
        ftl: FtlConfig {
            flash: FlashConfig {
                channels: 16,
                dies_per_channel: 4,
                blocks_per_die: 32,
                pages_per_block: 64,
                ..Default::default()
            },
            ..Default::default()
        },
        ..Default::default()
    };
    let image_bytes = 16 * 1024; // one 16 KiB flash page per image
    let dataset = Dataset::new(DatasetConfig {
        public_images: 70_000,
        private_per_csd: vec![9_910; 4],
        hw: 8,
        classes: 4,
        seed: 7,
        noise: 0.5,
    })
    .expect("dataset");
    let placement = balance(&dataset, 4, 25, 150, true).expect("balance");
    let admit = |ds: Dataset| {
        let mut plane = DataPlane::new(image_bytes);
        let mut pool = DevicePool::new(4, &csd_cfg);
        let mut tun = Tunnel::new(4, TunnelConfig::default());
        let t0 = Instant::now();
        let cost = plane
            .admit(
                JobId(0),
                ds,
                &placement,
                &[0, 1, 2, 3],
                true,
                25,
                150,
                1 << 20,
                4 * image_bytes as u64,
                &mut pool,
                &mut tun,
                SimTime::ZERO,
            )
            .expect("admit");
        (t0.elapsed().as_secs_f64(), cost, plane, pool, tun)
    };
    let (_, warm_cost, ..) = admit(dataset.clone()); // warm-up + sanity
    assert!(warm_cost.pages_written > 90_000, "layout must stage ~100k images");
    let (admission_wall, cost, mut plane, mut pool, mut tun) = admit(dataset.clone());
    println!(
        "\nadmission layout: {} images as {} flash pages in {} s wall",
        dataset.len(),
        cost.pages_written,
        f(admission_wall, 3)
    );

    // --- Degraded-fleet rebalance window ----------------------------------
    let redeal =
        balance_weighted(&dataset, 4, 25, 150, true, &[0.5, 1.0, 1.0, 1.0]).expect("redeal");
    let t0 = Instant::now();
    let rcost = plane
        .rebalance(
            JobId(0),
            &redeal,
            true,
            25,
            150,
            1 << 20,
            4 * image_bytes as u64,
            &mut pool,
            &mut tun,
            SimTime::secs(100),
        )
        .expect("rebalance");
    let rebalance_wall = t0.elapsed().as_secs_f64();
    assert!(rcost.images_moved > 0, "health flip must move the public top-up");
    println!(
        "rebalance: {} images moved ({} bytes) in {} s wall, lock wait {}",
        rcost.images_moved,
        rcost.bytes_moved,
        f(rebalance_wall, 4),
        rcost.lock_wait
    );

    record_bench_json_to(
        BENCH_JSON,
        "storage",
        &[
            ("ftl_write_run_pages_per_sec", write_run_pps),
            ("ftl_write_per_page_pages_per_sec", write_page_pps),
            ("ftl_read_run_pages_per_sec", read_run_pps),
            ("gc_victim_index_speedup", victim_speedup),
            ("admission_layout_100k_images_wall_s", admission_wall),
            ("rebalance_extent_wall_s", rebalance_wall),
        ],
    );
}
