//! Bench: the streaming runtime at fleet scale — a million-arrival
//! Poisson trace driven end-to-end through the chunked trace driver
//! (flat live set, slab slots reused), and the multi-seed sweep
//! harness at 1/2/4 workers. Before recording anything the bench
//! asserts (a) streaming totals are bit-identical to the
//! retained-everything oracle and (b) the merged sweep report is
//! bit-identical to the sequential (1-worker) run.
//!
//! Emits machine-readable numbers to `BENCH_6.json` (section
//! `"sweep"`).
//!
//! Run: `cargo bench --bench sweep`

// Benches are wall-clock consumers by definition; the crate-wide
// clippy gate on time sources is lifted per bench target.
#![allow(clippy::disallowed_methods)]

use std::time::Instant;

use stannis::config::{CancelSpec, ExperimentConfig, WeightedJob, WorkloadSpec};
use stannis::fleet::{run_sweep, run_trace};
use stannis::metrics::{f, print_table, record_bench_json_to};

const POOL: usize = 24;

/// Host-free, small-dataset mix: admission stays cheap and the host
/// never serializes the fleet, so the trace exercises the streaming
/// machinery rather than one shared bottleneck.
fn lean_mix() -> Vec<WeightedJob> {
    vec![
        WeightedJob {
            weight: 3.0,
            job: ExperimentConfig {
                network: "mobilenet_v2".into(),
                num_csds: 3,
                include_host: false,
                steps: 20,
                public_images: 384,
                private_per_csd: 64,
                ..Default::default()
            },
        },
        WeightedJob {
            weight: 1.0,
            job: ExperimentConfig {
                network: "squeezenet".into(),
                num_csds: 2,
                include_host: false,
                steps: 15,
                public_images: 256,
                private_per_csd: 64,
                ..Default::default()
            },
        },
    ]
}

fn main() {
    // --- Guard 1: streaming must be bit-identical to retained ------------
    let guard = WorkloadSpec {
        total_csds: POOL,
        stage_io: false,
        data_plane: false,
        jobs: 500,
        mean_interarrival_secs: 12.0,
        seed: 17,
        mix: lean_mix(),
        cancels: (0..500)
            .step_by(7)
            .map(|i| CancelSpec { job: i, at_secs: 6.0 + 12.0 * i as f64 })
            .collect(),
        ..Default::default()
    };
    let streaming = run_trace(&guard).expect("streaming guard trace");
    let mut retained_spec = guard.clone();
    retained_spec.retain_jobs = true;
    let retained = run_trace(&retained_spec).expect("retained guard trace");
    assert_eq!(streaming.makespan, retained.makespan, "streaming must not change the timeline");
    assert_eq!(streaming.total_images, retained.total_images);
    assert_eq!(streaming.completed, retained.completed);
    assert_eq!(streaming.cancelled, retained.cancelled);
    assert_eq!(
        streaming.jobs_energy_j.to_bits(),
        retained.jobs_energy_j.to_bits(),
        "streaming must be energy-bit-identical to the retained oracle"
    );
    assert_eq!(streaming.queue_wait, retained.queue_wait);
    assert_eq!(streaming.peak_live_jobs, retained.peak_live_jobs);
    assert_eq!(retained.job_slots, guard.jobs, "the oracle materializes every arrival");
    assert!(
        streaming.job_slots <= streaming.peak_live_jobs,
        "streaming slots {} must stay under the live high-water {}",
        streaming.job_slots,
        streaming.peak_live_jobs
    );

    // --- Million-arrival trace --------------------------------------------
    const TRACE_JOBS: usize = 1_000_000;
    let trace = WorkloadSpec {
        total_csds: POOL,
        stage_io: false,
        data_plane: false,
        jobs: TRACE_JOBS,
        mean_interarrival_secs: 12.0,
        seed: 17,
        mix: lean_mix(),
        ..Default::default()
    };
    let t0 = Instant::now();
    let s = run_trace(&trace).expect("million-arrival trace");
    let trace_wall = t0.elapsed().as_secs_f64();
    assert_eq!(s.completed, TRACE_JOBS, "every arrival must run to completion");
    // 3-CSD jobs on a 24-bay host-free pool: at most 8 concurrent, at
    // any trace length — the O(live jobs) claim, asserted, not assumed.
    assert!(
        s.peak_live_jobs <= POOL / 2,
        "peak live jobs {} must be bounded by pool concurrency, not trace length",
        s.peak_live_jobs
    );
    assert!(
        s.job_slots <= s.peak_live_jobs,
        "job table grew {} slots for {} arrivals",
        s.job_slots,
        TRACE_JOBS
    );
    let events_per_sec = s.log_events as f64 / trace_wall.max(1e-9);
    let hours = s.makespan.as_secs_f64() / 3600.0;
    let trace_jobs_per_hour = s.completed as f64 / hours.max(1e-12);
    println!(
        "1M-arrival trace: {} events in {:.2}s wall ({:.0} events/s), makespan {}, {:.1} jobs/h sustained, peak {} live, {} slot(s)",
        s.log_events, trace_wall, events_per_sec, s.makespan, trace_jobs_per_hour,
        s.peak_live_jobs, s.job_slots,
    );

    // --- Sweep scaling: 1 / 2 / 4 workers ---------------------------------
    const SWEEP_TRACE_JOBS: usize = 20_000;
    let base = WorkloadSpec { jobs: SWEEP_TRACE_JOBS, ..trace.clone() };
    let seeds: Vec<u64> = (0..4).map(|i| base.seed + i).collect();
    let mut rows = Vec::new();
    let mut walls = [0.0f64; 3];
    let mut reference = None;
    for (i, workers) in [1usize, 2, 4].into_iter().enumerate() {
        let t0 = Instant::now();
        let rep = run_sweep(&base, &seeds, workers).expect("sweep");
        walls[i] = t0.elapsed().as_secs_f64();
        // --- Guard 2: merged results must not depend on worker count ------
        match &reference {
            None => reference = Some(rep.clone()),
            Some(r) => assert_eq!(
                r, &rep,
                "sweep at {workers} workers must be bit-identical to sequential"
            ),
        }
        rows.push(vec![
            workers.to_string(),
            rep.traces.len().to_string(),
            rep.total_jobs.to_string(),
            f(rep.jobs_per_hour.mean(), 1),
            f(rep.aggregate_ips.mean(), 1),
            format!("{:.3} s", walls[i]),
            f(walls[0] / walls[i].max(1e-9), 2),
        ]);
    }
    print_table(
        &format!("Sweep scaling — 4 seeded traces x {SWEEP_TRACE_JOBS} arrivals, merged == sequential asserted"),
        &["workers", "traces", "jobs", "jobs/h", "img/s", "wall", "speedup"],
        &rows,
    );

    record_bench_json_to(
        "BENCH_6.json",
        "sweep",
        &[
            ("trace_jobs", TRACE_JOBS as f64),
            ("trace_wall_s", trace_wall),
            ("trace_events_per_sec", events_per_sec),
            ("trace_jobs_per_hour", trace_jobs_per_hour),
            ("trace_peak_live_jobs", s.peak_live_jobs as f64),
            ("trace_job_slots", s.job_slots as f64),
            ("sweep_wall_1w_s", walls[0]),
            ("sweep_wall_2w_s", walls[1]),
            ("sweep_wall_4w_s", walls[2]),
            ("sweep_speedup_4w", walls[0] / walls[2].max(1e-9)),
        ],
    );
}
