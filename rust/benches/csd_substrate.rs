//! Bench: the CSD substrate itself — the ISP-path vs host-path data
//! movement asymmetry (the paper's §III hardware claim) plus FTL/GC
//! throughput under sustained load.
//!
//! Run: `cargo bench --bench csd_substrate`

use stannis::csd::{CsdConfig, NewportCsd};
use stannis::metrics::{bench, f, print_table};
use stannis::sim::SimTime;

fn fresh_csd(seed: u64) -> NewportCsd {
    let mut csd = NewportCsd::new(0, CsdConfig::default(), seed);
    for lpn in 0..4096u32 {
        csd.write_page(lpn, lpn as u64, SimTime::ZERO).unwrap();
    }
    csd
}

fn main() {
    // --- The paper's data-path asymmetry ---------------------------------
    // Reads start after the preload programs drain (t0); the "contended"
    // column adds a concurrent allreduce burst on the PCIe link — the
    // regime a training epoch actually runs in, where the ISP path's
    // bypass of the NVMe link pays off.
    let t0 = SimTime::secs(10);
    let mut rows = Vec::new();
    for batch_pages in [16usize, 64, 256, 1024] {
        let lpns: Vec<u32> = (0..batch_pages as u32).collect();
        let mut a = fresh_csd(1);
        let host = a.read_for_host(&lpns, t0).unwrap() - t0;
        let mut b = fresh_csd(1);
        let isp = b.read_for_isp(&lpns, t0).unwrap() - t0;
        // Contended: 14 MB of gradient sync in flight on the same link.
        let mut c = fresh_csd(1);
        c.tunnel_transfer(13_880_000, t0);
        let host_cont = c.read_for_host(&lpns, t0).unwrap() - t0;
        let mut d = fresh_csd(1);
        d.tunnel_transfer(13_880_000, t0);
        let isp_cont = d.read_for_isp(&lpns, t0).unwrap() - t0;
        rows.push(vec![
            batch_pages.to_string(),
            format!("{host}"),
            format!("{isp}"),
            format!("{}x", f(host.as_ns() as f64 / isp.as_ns() as f64, 2)),
            format!("{host_cont}"),
            format!("{isp_cont}"),
            format!("{}x", f(host_cont.as_ns() as f64 / isp_cont.as_ns() as f64, 2)),
        ]);
    }
    print_table(
        "ISP path vs host path — staging latency (idle link | link carrying gradient sync)",
        &["pages", "host path", "ISP path", "adv", "host+sync", "ISP+sync", "adv"],
        &rows,
    );

    // --- Simulator throughput (how fast the DES itself runs) -------------
    let r = bench("ftl_write_4k_pages", 1, 10, || {
        let mut csd = NewportCsd::new(0, CsdConfig::default(), 7);
        for lpn in 0..4096u32 {
            csd.write_page(lpn, 0, SimTime::ZERO).unwrap();
        }
        std::hint::black_box(&csd);
    });
    println!("\n{}", r.summary());
    println!("    {:.1}M simulated page-writes/sec", 4096.0 / r.mean_secs() / 1e6);

    let r = bench("ftl_sustained_overwrite_with_gc", 1, 5, || {
        let mut csd = NewportCsd::new(0, CsdConfig::default(), 9);
        let logical = 4096u32;
        for round in 0..4u64 {
            for lpn in 0..logical {
                csd.write_page(lpn, round, SimTime::ZERO).unwrap();
            }
        }
        std::hint::black_box(csd.ftl_ref().stats().waf());
    });
    println!("{}", r.summary());

    let r = bench("isp_batch_staging_64_pages", 2, 20, || {
        let mut csd = fresh_csd(3);
        let lpns: Vec<u32> = (0..64).collect();
        std::hint::black_box(csd.read_for_isp(&lpns, SimTime::ZERO).unwrap());
    });
    println!("{}", r.summary());
}
