//! Bench: the ring-allreduce hot path (real f32 reduction) and the
//! modeled sync-time ablation (ring vs parameter server over the
//! PCIe-star tunnel).
//!
//! Run: `cargo bench --bench allreduce`

use stannis::allreduce::{param_server_time, ring_allreduce_mean, ring_time};
use stannis::metrics::{bench, f, print_table};
use stannis::sim::SimTime;
use stannis::tunnel::{NodeId, Tunnel, TunnelConfig};
use stannis::util::Rng;

fn replicas(n: usize, len: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| (0..len).map(|_| rng.f32()).collect()).collect()
}

fn main() {
    // --- Numeric hot path: the real-exec trainer calls this every step.
    // MobileNetV2-scale paper gradients: 3.47M f32.
    for (n, len) in [(2usize, 3_470_000usize), (7, 3_470_000), (25, 3_470_000), (7, 48_064)] {
        let base = replicas(n, len, 42);
        let mut bufs = base.clone();
        let r = bench(&format!("ring_allreduce_mean n={n} len={len}"), 1, 12, || {
            // copy-in is part of the measured loop by design: the
            // trainer rebuilds flat buffers each step.
            bufs.clone_from(&base);
            ring_allreduce_mean(&mut bufs).unwrap();
            std::hint::black_box(&bufs);
        });
        println!("{}", r.summary());
        let bytes_moved = 2.0 * (len * 4) as f64 * (n as f64 - 1.0);
        println!(
            "    effective reduce rate {:.2} GB/s",
            bytes_moved / r.mean_secs() / 1e9
        );
    }

    // --- Modeled sync ablation: ring vs parameter server -----------------
    let bytes = 13_880_000; // MobileNetV2 paper-scale grads
    let mut rows = Vec::new();
    for n in [2usize, 4, 8, 16, 24] {
        let ranks: Vec<NodeId> = std::iter::once(NodeId::Host)
            .chain((0..n).map(NodeId::Csd))
            .collect();
        let mut t1 = Tunnel::new(n, TunnelConfig::default());
        let ring = ring_time(&mut t1, &ranks, bytes, SimTime::ZERO);
        let mut t2 = Tunnel::new(n, TunnelConfig::default());
        let ps = param_server_time(&mut t2, &ranks, NodeId::Host, bytes, SimTime::ZERO);
        rows.push(vec![
            n.to_string(),
            f(ring.as_secs_f64(), 3),
            f(ps.as_secs_f64(), 3),
            f(ring.as_secs_f64() / ps.as_secs_f64(), 2),
        ]);
    }
    print_table(
        "Sync ablation — ring vs parameter-server over the PCIe star (13.88 MB grads)",
        &["CSDs", "ring (s)", "param-server (s)", "ring/PS"],
        &rows,
    );
    println!(
        "finding: on a star fabric the ring loses its bandwidth-optimality \
         (all csd<->csd hops relay through the root) — see EXPERIMENTS.md §Ablations."
    );
}
