//! Bench: regenerate paper Table I (parameter tuning from Algorithm 1)
//! plus the batch-size → throughput sweep the §V text describes.
//!
//! Run: `cargo bench --bench table1`

use stannis::coordinator::{tune, TuneConfig};
use stannis::metrics::{bench, f, print_table};
use stannis::perfmodel::{Device, PerfModel};

const NETS: [(&str, &str, &str, usize, usize, f64, f64); 4] = [
    // (name, paper params, paper MACs, paper bs host, bs newport, speed host, speed newport)
    ("mobilenet_v2", "3.47M", "56M", 315, 25, 31.05, 3.08),
    ("nasnet", "5.3M", "564M", 325, 15, 47.31, 2.80),
    ("inception_v3", "23.83M", "5.72G", 370, 16, 30.80, 1.85),
    ("squeezenet", "1.25M", "861M", 850, 50, 219.0, 16.3),
];

fn main() {
    let mut model = PerfModel::default();
    let cfg = TuneConfig::default();

    // --- Table I ---------------------------------------------------------
    let mut rows = Vec::new();
    for (net, params, macs, p_hbs, p_nbs, p_hips, p_nips) in NETS {
        let r = tune(&mut model, net, &cfg).unwrap();
        rows.push(vec![
            net.to_string(),
            params.to_string(),
            macs.to_string(),
            format!("{} / {}", r.host_bs, r.newport_bs),
            format!("{p_hbs} / {p_nbs}"),
            format!("{} / {}", f(r.host_ips, 2), f(r.newport_ips, 2)),
            format!("{p_hips} / {p_nips}"),
        ]);
    }
    print_table(
        "Table I — Algorithm 1 parameter tuning",
        &[
            "network",
            "params",
            "MACs",
            "batch h/n (ours)",
            "batch h/n (paper)",
            "img/s h/n (ours)",
            "img/s h/n (paper)",
        ],
        &rows,
    );

    // --- §V batch sweep: throughput saturation on Newport ----------------
    let mut rows = Vec::new();
    for bs in [1usize, 2, 4, 8, 16, 25, 32, 64, 128] {
        let ips = model.ips(Device::NewportIsp, "mobilenet_v2", bs).unwrap();
        let hips = model.ips(Device::HostXeon, "mobilenet_v2", bs).unwrap();
        rows.push(vec![bs.to_string(), f(ips, 3), f(hips, 2)]);
    }
    print_table(
        "MobileNetV2 throughput vs batch size (saturation, §V)",
        &["batch", "newport img/s", "host img/s"],
        &rows,
    );

    // --- Tuner cost ------------------------------------------------------
    let r = bench("algorithm1_tune(mobilenet_v2)", 3, 50, || {
        let mut m = PerfModel::default();
        std::hint::black_box(tune(&mut m, "mobilenet_v2", &cfg).unwrap());
    });
    println!("\n{}", r.summary());
}
