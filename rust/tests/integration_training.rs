//! Integration: the full tune → balance → train pipeline with real
//! PJRT execution, shared across tests via one global engine (artifact
//! compilation is expensive; numerics are deterministic).

use std::sync::Arc;

use stannis::cluster::Cluster;
use stannis::config::ExperimentConfig;
use stannis::coordinator::balance;
use stannis::data::{Dataset, Visibility};
use stannis::runtime::{default_artifacts_dir, Engine};

// The xla PJRT client is Rc-based (!Send), so tests that need the
// engine share ONE instance inside a single sequential #[test] — this
// also pays the artifact-compilation cost exactly once.

fn small_cfg() -> ExperimentConfig {
    ExperimentConfig {
        network: "mobilenet_v2_s".into(),
        num_csds: 3,
        include_host: true,
        bs_csd: 2,
        bs_host: 8,
        steps: 8,
        base_lr: 0.01,
        momentum: 0.9,
        warmup_steps: 0,
        public_images: 256,
        private_per_csd: 64,
        seed: 3,
        reference_batch: 32,
    }
}

fn distributed_training_runs_and_descends(engine: &Arc<Engine>) {
    let cluster = Cluster::bring_up_with_engine(small_cfg(), engine.clone()).unwrap();
    let mut trainer = cluster.trainer().unwrap();
    assert_eq!(trainer.num_workers(), 4);
    let report = trainer.train(8).unwrap();
    assert_eq!(report.losses.len(), 8);
    assert!(report.losses.iter().all(|l| l.is_finite()));
    assert_eq!(report.images_processed, 8 * (8 + 3 * 2));
    // Lockstep: replicas must not diverge at all (identical averaged
    // grads + identical optimizer state).
    assert_eq!(trainer.replica_divergence(), 0.0);
}

fn single_worker_descends(engine: &Arc<Engine>) {
    let cfg = ExperimentConfig { num_csds: 0, bs_host: 16, steps: 12, ..small_cfg() };
    let cluster = Cluster::bring_up_with_engine(cfg, engine.clone()).unwrap();
    let mut trainer = cluster.trainer().unwrap();
    assert_eq!(trainer.num_workers(), 1);
    let report = trainer.train(12).unwrap();
    // Over 12 steps on a 256-image pool, loss should trend down.
    let head: f32 = report.losses[..3].iter().sum::<f32>() / 3.0;
    let tail: f32 = report.losses[9..].iter().sum::<f32>() / 3.0;
    assert!(tail < head, "loss should descend: head {head:.4} tail {tail:.4}");
}

fn csd_only_cluster_trains(engine: &Arc<Engine>) {
    // The paper's second deployment scenario (§V): standalone CSDs, no
    // host participation in training.
    let cfg = ExperimentConfig {
        num_csds: 2,
        include_host: false,
        steps: 4,
        ..small_cfg()
    };
    let cluster = Cluster::bring_up_with_engine(cfg, engine.clone()).unwrap();
    let mut trainer = cluster.trainer().unwrap();
    assert_eq!(trainer.num_workers(), 2);
    let report = trainer.train(4).unwrap();
    assert_eq!(report.images_processed, 4 * 2 * 2);
    assert_eq!(trainer.replica_divergence(), 0.0);
}

fn different_worker_counts_reach_similar_loss(engine: &Arc<Engine>) {
    // §V.C parity in miniature: same per-step image budget, 1 vs 3 workers.
    let steps = 10;
    let cfg1 = ExperimentConfig {
        num_csds: 0,
        bs_host: 8,
        steps,
        warmup_steps: 2,
        ..small_cfg()
    };
    let cfg3 = ExperimentConfig {
        num_csds: 2,
        include_host: true,
        bs_csd: 2,
        bs_host: 4,
        steps,
        warmup_steps: 2,
        ..small_cfg()
    };
    let c1 = Cluster::bring_up_with_engine(cfg1, engine.clone()).unwrap();
    let c3 = Cluster::bring_up_with_engine(cfg3, engine.clone()).unwrap();
    let r1 = c1.trainer().unwrap().train(steps).unwrap();
    let r3 = c3.trainer().unwrap().train(steps).unwrap();
    // Both descend and land in the same ballpark (generous band — ten
    // steps of SGD on synthetic data is noisy).
    assert!(r1.last_loss().is_finite() && r3.last_loss().is_finite());
    let rel = (r1.last_loss() - r3.last_loss()).abs() / r1.last_loss();
    assert!(rel < 0.6, "1-worker {:.4} vs 3-worker {:.4}", r1.last_loss(), r3.last_loss());
}

#[test]
fn placement_respects_privacy_in_full_pipeline() {
    let cfg = small_cfg();
    let dataset = Dataset::new(cfg.dataset()).unwrap();
    let p = balance(&dataset, cfg.num_csds, cfg.bs_csd, cfg.bs_host, true).unwrap();
    for &id in &p.host_ids {
        assert!(matches!(dataset.visibility(id).unwrap(), Visibility::Public));
    }
    for (c, ids) in p.csd_ids.iter().enumerate() {
        for &id in ids {
            if let Visibility::Private { csd } = dataset.visibility(id).unwrap() {
                assert_eq!(csd, c, "private image {id} leaked to csd{c}");
            }
        }
    }
}

fn missing_artifact_batch_size_fails_fast(engine: &Arc<Engine>) {
    let cfg = ExperimentConfig { bs_csd: 3, ..small_cfg() }; // 3 not compiled
    assert!(Cluster::bring_up_with_engine(cfg, engine.clone()).is_err());
}

fn evaluation_reports_sane_metrics(engine: &Arc<Engine>) {
    let cluster = Cluster::bring_up_with_engine(small_cfg(), engine.clone()).unwrap();
    let mut trainer = cluster.trainer().unwrap();
    trainer.train(4).unwrap();
    let (loss, acc) = trainer.evaluate(2).unwrap();
    assert!(loss.is_finite() && loss > 0.0);
    assert!((0.0..=1.0).contains(&acc));
}

#[test]
fn full_training_pipeline() {
    let dir = default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping full_training_pipeline: no AOT artifacts (run `make artifacts`)");
        return;
    }
    let engine = Arc::new(Engine::new(dir).expect("run `make artifacts`"));
    distributed_training_runs_and_descends(&engine);
    single_worker_descends(&engine);
    csd_only_cluster_trains(&engine);
    different_worker_counts_reach_similar_loss(&engine);
    evaluation_reports_sane_metrics(&engine);
    missing_artifact_batch_size_fails_fast(&engine);
}
