//! Ledger subsystem end-to-end (DESIGN.md §Ledger):
//!
//! * Replay fidelity — a randomized trace (cancels, crashes, faults)
//!   written through `--ledger` decodes back bit-identical to the
//!   in-memory retained oracle: every record field-for-field, the
//!   ordered energy sum to the bit, and byte-identical segment files
//!   across both executors. Ledger-off summaries are unchanged by
//!   arming a ledger.
//! * Keyset pagination — any page size walks the same
//!   `(retire_time, job_id, ordinal)` total order with no duplicates
//!   and no gaps, with and without a filter, including ledgers holding
//!   duplicate `(time, job)` keys that only the ordinal disambiguates.
//! * Sweep invariance — a swept ledger is byte-identical at any
//!   worker count.

use std::fs;
use std::path::{Path, PathBuf};

use stannis::config::{CancelSpec, CrashSpec, ExperimentConfig, FaultSpec, WeightedJob, WorkloadSpec};
use stannis::fleet::{run_sweep, run_trace_with, JobId, JobReport, JobState, RetiredRecord, RuntimeEvent};
use stannis::ledger::{self, Agg, Key, LedgerStore, LedgerWriter};
use stannis::analysis::audit::Auditable;
use stannis::sim::SimTime;

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("stannis_intl_{tag}_{}", std::process::id()));
    let _ = fs::remove_dir_all(&dir);
    dir
}

fn trace_mix() -> Vec<WeightedJob> {
    ["mobilenet_v2", "squeezenet"]
        .iter()
        .map(|net| WeightedJob {
            weight: 1.0,
            job: ExperimentConfig {
                network: (*net).into(),
                num_csds: 2,
                include_host: false,
                steps: 5,
                public_images: 256,
                private_per_csd: 64,
                ..Default::default()
            },
        })
        .collect()
}

fn faulty_spec(seed: u64, ff: bool) -> WorkloadSpec {
    WorkloadSpec {
        total_csds: 6,
        stage_io: false,
        fast_forward: ff,
        seed,
        jobs: 12,
        mean_interarrival_secs: 6.0,
        mix: trace_mix(),
        csds_per_job: 2,
        cancels: vec![
            CancelSpec { job: 2, at_secs: 9.0 },
            CancelSpec { job: 7, at_secs: 55.0 },
        ],
        faults: vec![FaultSpec { at_secs: 25.0, device: 1, factor: 0.7 }],
        crashes: vec![CrashSpec { at_secs: 40.0, device: 3 }],
        ..Default::default()
    }
}

/// Byte-compare every file under two directory trees (recursive,
/// name-sorted — the same order `LedgerStore::open` walks).
fn assert_trees_equal(a: &Path, b: &Path) {
    fn walk(dir: &Path, out: &mut Vec<PathBuf>) {
        let mut entries: Vec<PathBuf> =
            fs::read_dir(dir).unwrap().map(|e| e.unwrap().path()).collect();
        entries.sort();
        for p in entries {
            if p.is_dir() {
                walk(&p, out);
            } else {
                out.push(p);
            }
        }
    }
    let (mut fa, mut fb) = (Vec::new(), Vec::new());
    walk(a, &mut fa);
    walk(b, &mut fb);
    let rel = |base: &Path, ps: &[PathBuf]| -> Vec<PathBuf> {
        ps.iter().map(|p| p.strip_prefix(base).unwrap().to_path_buf()).collect()
    };
    assert_eq!(rel(a, &fa), rel(b, &fb), "directory shapes differ");
    for (pa, pb) in fa.iter().zip(&fb) {
        assert_eq!(
            fs::read(pa).unwrap(),
            fs::read(pb).unwrap(),
            "{} and {} differ",
            pa.display(),
            pb.display()
        );
    }
}

/// (a) Replay fidelity: the decoded ledger IS the retained oracle's
/// record stream — same records in the same order, every field exact,
/// the ordered energy sum bit-equal to the summary's jobs total — and
/// the segment bytes are executor-independent. Arming the ledger
/// changes nothing else: the summary equals a ledger-off run's.
#[test]
fn ledger_replay_is_bit_identical_to_the_oracle() {
    for (i, seed) in [3u64, 17, 90210].into_iter().enumerate() {
        for ff in [true, false] {
            let dir = tmp_dir(&format!("replay_{i}_{ff}"));
            let mut spec = faulty_spec(seed, ff);
            spec.ledger = Some(dir.clone());

            // The oracle: every Retired record as it streams off the log.
            let mut oracle: Vec<RetiredRecord> = Vec::new();
            let (summary, _rt) = run_trace_with(&spec, |e| {
                if let RuntimeEvent::Retired { record } = &e.event {
                    oracle.push((**record).clone());
                }
            })
            .expect("ledger-armed trace runs");

            // Ledger-off control: identical trace, no ledger — the
            // summary (incl. exact f64 fields) must not move.
            let mut off = faulty_spec(seed, ff);
            off.ledger = None;
            let (off_summary, _) = run_trace_with(&off, |_| {}).expect("ledger-off trace");
            assert_eq!(summary, off_summary, "arming a ledger changed the run (ff={ff})");

            let store = LedgerStore::open(&dir).expect("sealed ledger opens");
            store.audit().expect("deep audit passes");
            let decoded = store.read_all().expect("decodes");
            assert_eq!(decoded.len(), oracle.len(), "record count (ff={ff})");
            let mut energy = 0.0f64;
            for ((ordinal, got), want) in decoded.iter().zip(&oracle) {
                assert_eq!(got, want, "record {ordinal} differs (ff={ff})");
                energy += got.report.energy_j;
            }
            // Retirement order is the accumulation order `FleetTotals`
            // uses, so the sums agree to the bit.
            assert_eq!(
                energy.to_bits(),
                summary.jobs_energy_j.to_bits(),
                "ordered ledger energy sum must be bitwise-equal (ff={ff})"
            );
            // Faults really fired (the trace is not a trivial one).
            if i == 0 {
                assert!(oracle.iter().any(|r| r.report.state == JobState::Cancelled));
            }
        }
        // Executor independence: per-step and fast-forward wrote
        // byte-identical segment sets.
        assert_trees_equal(
            &tmp_dir_existing(&format!("replay_{i}_true")),
            &tmp_dir_existing(&format!("replay_{i}_false")),
        );
        for ff in [true, false] {
            let _ = fs::remove_dir_all(tmp_dir_existing(&format!("replay_{i}_{ff}")));
        }
    }
}

/// `tmp_dir` without the cleanup (to reopen a dir a test just wrote).
fn tmp_dir_existing(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("stannis_intl_{tag}_{}", std::process::id()))
}

fn synth_record(job: u64, retired_ns: u64, energy: f64, crashed: bool) -> RetiredRecord {
    RetiredRecord {
        retired_at: SimTime(retired_ns),
        report: JobReport {
            id: JobId(job),
            state: if job % 4 == 0 { JobState::Cancelled } else { JobState::Completed },
            network: format!("net{}", job % 3),
            devices: vec![(job % 5) as usize],
            held_host: false,
            bs_csd: 8,
            bs_host: 0,
            steps_done: 3,
            steps_per_epoch: 3,
            images: 24,
            submitted_at: SimTime(0),
            admitted_at: SimTime(1),
            finished_at: SimTime(retired_ns),
            queue_wait: SimTime(job * 1_000_000),
            elapsed: SimTime(retired_ns - 1),
            images_per_sec: 5.0 + job as f64,
            sync_fraction: 0.1,
            energy_j: energy,
            j_per_image: energy / 24.0,
            link_bytes: 0,
            bytes_moved: 0,
            images_moved: 0,
            lock_wait: SimTime(0),
            retunes: 0,
            drained: false,
            crashed,
            lost_steps: 0,
            checkpoint_bytes: 0,
        },
    }
}

/// (b) Cursor pagination: walking the ledger at any page size yields
/// exactly the full listing — no duplicates, no gaps, `next == None`
/// only at the true end — with and without a filter. The synthesized
/// ledger deliberately contains duplicate `(retire_time, job_id)`
/// pairs so only the ordinal tiebreaker keeps the order total.
#[test]
fn pagination_walks_the_same_total_order_at_any_page_size() {
    stannis::util::prop::check_n("ledger cursor pagination", 6, |rng| {
        let tag = format!("page_{}", rng.below(u64::MAX));
        let dir = tmp_dir(&tag);
        let mut w = LedgerWriter::new(dir.clone());
        let n = 200 + rng.usize_below(400);
        for _ in 0..n {
            // Coarse time buckets + small job-id range force ties.
            let t = 1_000_000 * (1 + rng.below(40));
            let job = rng.below(30);
            w.append(&synth_record(job, t, rng.f64() * 50.0, rng.bool(0.2)));
        }
        w.finish().expect("seals");

        let store = LedgerStore::open(&dir).expect("opens");
        assert_eq!(store.records_total(), n as u64);

        for filter in [
            None,
            Some(ledger::compile("energy_j < 25 and crashed = false").unwrap()),
        ] {
            // Ground truth: one giant page.
            let full = ledger::page(&store, filter.as_ref(), None, n + 1).expect("full page");
            assert!(full.next.is_none(), "a page holding everything has no next");
            let want: Vec<Key> = full.records.iter().map(|(k, _)| *k).collect();
            // The order really is total and strictly increasing.
            assert!(want.windows(2).all(|p| p[0] < p[1]), "keys must strictly increase");

            for page_size in [1usize, 2, 3, 7, 64] {
                let mut got: Vec<Key> = Vec::new();
                let mut cursor: Option<Key> = None;
                loop {
                    let p = ledger::page(&store, filter.as_ref(), cursor, page_size)
                        .expect("page");
                    assert!(p.records.len() <= page_size);
                    got.extend(p.records.iter().map(|(k, _)| *k));
                    match p.next {
                        Some(c) => {
                            assert_eq!(
                                p.records.len(),
                                page_size,
                                "a continued page must be full"
                            );
                            cursor = Some(ledger::decode_cursor(&c).expect("own cursor decodes"));
                        }
                        None => break,
                    }
                }
                assert_eq!(got, want, "page size {page_size} diverged from the full walk");
            }
        }

        // Aggregates agree with a by-hand fold over the full listing.
        let filter = ledger::compile("crashed = false").unwrap();
        let full = ledger::page(&store, Some(&filter), None, n + 1).unwrap();
        let aggs = ledger::aggregate(
            &store,
            Some(&filter),
            &[Agg::Count, Agg::Sum(ledger::Field::EnergyJ)],
        )
        .unwrap();
        assert_eq!(aggs[0].1 as usize, full.records.len());
        let hand: f64 = full.records.iter().map(|(_, r)| r.report.energy_j).sum::<f64>();
        assert!((aggs[1].1 - hand).abs() <= 1e-9 * hand.abs().max(1.0));

        let _ = fs::remove_dir_all(&dir);
    });
}

/// (c) Sweep worker-count invariance extends to the ledger: per-seed
/// subdirectories merged in seed order are byte-identical at any
/// worker count, and the merged store opens and audits as one ledger.
#[test]
fn sweep_ledgers_are_byte_identical_at_any_worker_count() {
    let seeds: Vec<u64> = vec![11, 12, 13, 14, 15];
    let mut dirs = Vec::new();
    for workers in [1usize, 3] {
        let dir = tmp_dir(&format!("sweep_{workers}"));
        let mut base = faulty_spec(11, true);
        base.ledger = Some(dir.clone());
        let rep = run_sweep(&base, &seeds, workers).expect("sweep runs");
        assert_eq!(rep.traces.len(), seeds.len());
        dirs.push(dir);
    }
    assert_trees_equal(&dirs[0], &dirs[1]);

    // The merged multi-seed directory is itself one queryable ledger:
    // every per-seed subdirectory chain audits, and the record count
    // is the sum of the traces' retirements.
    let store = LedgerStore::open(&dirs[0]).expect("merged ledger opens");
    store.audit().expect("merged audit passes");
    assert!(store.segments().len() >= seeds.len(), "at least one segment per seed");
    let all = store.read_all().expect("merged read");
    assert_eq!(all.len() as u64, store.records_total());
    assert!(all.len() >= seeds.len() * 12, "every trace contributed its retirements");
    for dir in dirs {
        let _ = fs::remove_dir_all(&dir);
    }
}
