//! Fleet coordinator end-to-end: concurrent jobs on one device pool,
//! per-job tuning/balancing, degradation-driven re-tuning that leaves
//! co-tenants untouched, metric conservation, and the online session
//! API's bit-identity to the batch façade (DESIGN.md §5, §Runtime).

use stannis::config::ExperimentConfig;
use stannis::fleet::{Fleet, FleetConfig, FleetReport, FleetRuntime, JobState};
use stannis::sim::SimTime;

fn job(network: &str, num_csds: usize, include_host: bool, steps: usize) -> ExperimentConfig {
    ExperimentConfig {
        network: network.into(),
        num_csds,
        include_host,
        steps,
        ..Default::default()
    }
}

fn fleet(total_csds: usize, stage_io: bool) -> Fleet {
    Fleet::new(FleetConfig { total_csds, stage_io, ..Default::default() })
}

/// (a) Two concurrent jobs on disjoint device groups both converge
/// their schedules: Algorithm 1 tunes each group to its own network's
/// paper batches and Eq. 1 gives each a consistent epoch shape.
#[test]
fn two_concurrent_jobs_converge_schedules() {
    let mut fl = fleet(8, true);
    let a = fl.submit(job("mobilenet_v2", 3, true, 6));
    let b = fl.submit(job("squeezenet", 4, false, 6));
    let r = fl.run().unwrap();
    assert_eq!(r.jobs.len(), 2);
    let (ja, jb) = (&r.jobs[0], &r.jobs[1]);
    assert_eq!(ja.id, a);
    assert_eq!(jb.id, b);

    // Disjoint groups, both admitted immediately (true concurrency).
    assert!(ja.devices.iter().all(|d| !jb.devices.contains(d)));
    assert_eq!(ja.admitted_at, SimTime::ZERO);
    assert_eq!(jb.admitted_at, SimTime::ZERO);
    assert!(ja.held_host && !jb.held_host);

    // Algorithm 1 per group: paper Table I batches for each network.
    assert_eq!(ja.bs_csd, 25, "mobilenet Newport batch");
    assert!((ja.bs_host as i64 - 315).unsigned_abs() <= 16, "host bs {}", ja.bs_host);
    assert!((jb.bs_csd as i64 - 50).unsigned_abs() <= 10, "squeezenet Newport batch {}", jb.bs_csd);

    // Eq. 1 per group: steps_per_epoch = ceil(private_shard / bs_csd).
    let private = ExperimentConfig::default().private_per_csd;
    assert_eq!(ja.steps_per_epoch, private.div_ceil(ja.bs_csd));
    assert_eq!(jb.steps_per_epoch, private.div_ceil(jb.bs_csd));

    // Both ran their full schedule and made progress.
    assert_eq!(ja.steps_done, 6);
    assert_eq!(jb.steps_done, 6);
    assert_eq!(ja.images, 6 * (3 * ja.bs_csd + ja.bs_host));
    assert_eq!(jb.images, 6 * (4 * jb.bs_csd));
    assert!(ja.sync_fraction > 0.0 && jb.sync_fraction > 0.0);
    assert_eq!(r.retunes, 0);
}

/// Run the (b) scenario twice: identical two-job fleets, one with a
/// mid-run degradation on a device of job A.
fn degradation_pair() -> (FleetReport, FleetReport) {
    let build = || {
        let mut fl = fleet(8, true);
        // A: long-running, holds the host, devices 0..=2.
        fl.submit(job("mobilenet_v2", 3, true, 8));
        // B: CSD-only co-tenant, devices 3..=6, finishes while A runs.
        fl.submit(job("squeezenet", 4, false, 12));
        fl
    };
    let clean = build().run().unwrap();
    let mut faulted_fleet = build();
    // Device 0 belongs to job A (deterministic lowest-index carve);
    // throttle it to 60% at t=30s, mid-run for both jobs.
    faulted_fleet.inject_degradation(SimTime::secs(30), 0, 0.6);
    let faulted = faulted_fleet.run().unwrap();
    (clean, faulted)
}

/// (b) Mid-run degradation of one device re-tunes only the affected
/// job; the co-tenant's entire report is bit-identical.
#[test]
fn degradation_retunes_only_affected_job() {
    let (clean, faulted) = degradation_pair();
    let (a_clean, b_clean) = (&clean.jobs[0], &clean.jobs[1]);
    let (a_faulted, b_faulted) = (&faulted.jobs[0], &faulted.jobs[1]);

    // The affected job re-tuned exactly once and slowed down.
    assert_eq!(a_clean.retunes, 0);
    assert_eq!(a_faulted.retunes, 1);
    assert!(
        a_faulted.finished_at > a_clean.finished_at,
        "degraded job must slow: {} !> {}",
        a_faulted.finished_at,
        a_clean.finished_at
    );
    // Re-tuning at the degraded speed grows the host batch to keep the
    // Eq. 1 margin (same behaviour as integration_faults' whole-cluster
    // case, now scoped to one job's group).
    assert!(
        a_faulted.bs_host > a_clean.bs_host,
        "host batch must grow to match the slower group: {} !> {}",
        a_faulted.bs_host,
        a_clean.bs_host
    );
    assert_eq!(a_faulted.bs_csd, a_clean.bs_csd, "Newport saturation batch does not move");

    // The data plane physically moved the re-dealt public shards of
    // the affected job only, under DLM locks.
    assert!(a_faulted.bytes_moved > 0, "rebalance must move the public delta");
    assert!(a_faulted.images_moved > 0);
    assert_eq!(a_clean.bytes_moved, 0, "no fault, no movement");
    assert!(
        a_faulted.lock_wait > a_clean.lock_wait,
        "shard-map EX grants cross the tunnel during the movement window"
    );

    // The co-tenant is untouched in every observable.
    assert_eq!(b_faulted.retunes, 0);
    assert_eq!(b_faulted.bs_csd, b_clean.bs_csd);
    assert_eq!(b_faulted.steps_done, b_clean.steps_done);
    assert_eq!(b_faulted.images, b_clean.images);
    assert_eq!(b_faulted.finished_at, b_clean.finished_at);
    assert_eq!(b_faulted.link_bytes, b_clean.link_bytes);
    assert_eq!(b_faulted.bytes_moved, b_clean.bytes_moved);
    assert!((b_faulted.energy_j - b_clean.energy_j).abs() < 1e-9);

    // Ledger conservation survives the fault: the abandoned step's ring
    // traffic stays attributed to the affected job, so fabric totals
    // still equal the per-job sums.
    let link: u64 = faulted.jobs.iter().map(|j| j.link_bytes).sum();
    assert_eq!(faulted.link_bytes, link);
}

/// (c) Fleet-wide metrics are conserved: totals equal the sum of the
/// per-job metrics (shared-chassis overhead is ledgered separately).
#[test]
fn fleet_metrics_sum_to_per_job_metrics() {
    let mut fl = fleet(10, true);
    fl.submit(job("mobilenet_v2", 3, true, 5));
    fl.submit(job("squeezenet", 4, false, 5));
    fl.submit(job("nasnet", 3, false, 4));
    let r = fl.run().unwrap();
    assert_eq!(r.jobs.len(), 3);

    let images: usize = r.jobs.iter().map(|j| j.images).sum();
    assert_eq!(r.total_images, images);

    let energy: f64 = r.jobs.iter().map(|j| j.energy_j).sum();
    assert!(
        (r.jobs_energy_j - energy).abs() < 1e-6 * energy.max(1.0),
        "job energy ledger must be conservative: {} vs {}",
        r.jobs_energy_j,
        energy
    );
    assert!(
        (r.total_energy_j - (r.jobs_energy_j + r.overhead_energy_j)).abs() < 1e-9,
        "total = jobs + overhead"
    );
    assert!(r.overhead_energy_j > 0.0, "chassis overhead must be metered");

    // Every ring byte on the fabric is attributed to exactly one job.
    let link: u64 = r.jobs.iter().map(|j| j.link_bytes).sum();
    assert_eq!(r.link_bytes, link);

    let ips: f64 = r.total_images as f64 / r.makespan.as_secs_f64();
    assert!((r.aggregate_ips - ips).abs() < 1e-9);
}

/// Oversubscription: jobs queue and admit in waves as devices free up,
/// FIFO with backfill.
#[test]
fn oversubscribed_jobs_admit_in_waves() {
    let mut fl = fleet(4, false);
    let a = fl.submit(job("mobilenet_v2", 3, true, 3));
    let b = fl.submit(job("squeezenet", 3, false, 3)); // must wait for A
    let c = fl.submit(job("nasnet", 1, false, 3)); // backfills A's leftover
    let r = fl.run().unwrap();
    let find = |id| r.jobs.iter().find(|j| j.id == id).unwrap();
    let (ja, jb, jc) = (find(a), find(b), find(c));

    assert_eq!(ja.admitted_at, SimTime::ZERO);
    assert_eq!(jc.admitted_at, SimTime::ZERO, "small job must backfill the idle device");
    assert!(jb.queue_wait > SimTime::ZERO, "B must wait for a free group");
    assert_eq!(jb.admitted_at, ja.finished_at, "B admits the moment A releases");
    assert_eq!(r.queue_wait.count(), 3);
    assert!(r.queue_wait.max() >= jb.queue_wait.as_secs_f64());
}

/// A job demanding more devices than the pool holds is a hard error,
/// not silent starvation.
#[test]
fn unplaceable_job_is_an_error() {
    let mut fl = fleet(2, false);
    fl.submit(job("mobilenet_v2", 3, false, 2));
    assert!(fl.run().is_err());
}

/// The steady-state fast-forward is an *exact* optimization: across
/// randomized fleets (shapes, queueing, faults), the analytic path and
/// the per-step reference produce bit-identical times, step counts,
/// energy and link-byte totals.
#[test]
fn fast_forward_is_bit_identical_to_per_step() {
    stannis::util::prop::check_n("fleet fast-forward equivalence", 24, |rng| {
        let pool = 2 + rng.usize_below(5); // 2..=6 bays
        let n_jobs = 1 + rng.usize_below(3); // 1..=3 jobs
        let nets = ["mobilenet_v2", "squeezenet", "nasnet", "inception_v3"];
        let specs: Vec<ExperimentConfig> = (0..n_jobs)
            .map(|_| {
                let num_csds = rng.usize_below(pool + 1);
                ExperimentConfig {
                    network: nets[rng.usize_below(nets.len())].into(),
                    num_csds,
                    // Every job needs at least one worker.
                    include_host: num_csds == 0 || rng.bool(0.5),
                    steps: 1 + rng.usize_below(24),
                    ..Default::default()
                }
            })
            .collect();
        let faults: Vec<(u64, usize, f64)> = (0..rng.usize_below(3))
            .map(|_| {
                (rng.below(200_000_000_000), rng.usize_below(pool), 0.3 + 0.6 * rng.f64())
            })
            .collect();
        let run = |fast_forward: bool| {
            let mut fl = Fleet::new(FleetConfig {
                total_csds: pool,
                stage_io: false,
                fast_forward,
                ..Default::default()
            });
            for s in &specs {
                fl.submit(s.clone());
            }
            for &(at_ns, device, factor) in &faults {
                fl.inject_degradation(SimTime::ns(at_ns), device, factor);
            }
            let report = fl.run().unwrap();
            let transfers = fl.data_plane().transfers().to_vec();
            (report, transfers)
        };
        let (a, ta) = run(true);
        let (b, tb) = run(false);
        // The data plane stages and moves everything through the
        // extent (bulk I/O) path; the physical transfer ledger must be
        // untouched by how steps were batched.
        assert_eq!(ta, tb, "transfer ledger must be identical across executors");
        assert_eq!(a.makespan, b.makespan, "makespan must be bit-identical");
        assert_eq!(a.total_images, b.total_images);
        assert_eq!(a.link_bytes, b.link_bytes);
        assert_eq!(a.retunes, b.retunes);
        // Data-plane movement happens at structural events, which both
        // executors run identically — rebalance windows included.
        assert_eq!(a.bytes_moved, b.bytes_moved);
        assert_eq!(
            a.total_energy_j.to_bits(),
            b.total_energy_j.to_bits(),
            "energy must be bit-identical: {} vs {}",
            a.total_energy_j,
            b.total_energy_j
        );
        assert_eq!(a.overhead_energy_j.to_bits(), b.overhead_energy_j.to_bits());
        assert_eq!(a.jobs.len(), b.jobs.len());
        for (x, y) in a.jobs.iter().zip(&b.jobs) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.admitted_at, y.admitted_at);
            assert_eq!(x.finished_at, y.finished_at);
            assert_eq!(x.steps_done, y.steps_done);
            assert_eq!(x.images, y.images);
            assert_eq!(x.link_bytes, y.link_bytes);
            assert_eq!(x.retunes, y.retunes);
            assert_eq!(x.bytes_moved, y.bytes_moved);
            assert_eq!(x.images_moved, y.images_moved);
            assert_eq!(x.lock_wait, y.lock_wait);
            assert_eq!(x.energy_j.to_bits(), y.energy_j.to_bits());
        }
    });
}

/// Privacy invariant (paper §III, §V.C) over randomized fleets with
/// degradation-driven rebalances: no `Private { csd }` id ever appears
/// in any cross-node transfer (so in particular never in one whose
/// source or destination is not its home CSD), and the DLM invariants
/// hold at every grant — the data plane calls `Dlm::check_invariants`
/// after each request and release, so any violation fails the run
/// itself; the transfer ledger is re-audited here from the outside.
#[test]
fn privacy_invariant_over_randomized_rebalancing_fleets() {
    use stannis::data::{Dataset, Visibility};
    let mut total_transfers = 0u64;
    let mut total_retunes = 0usize;
    stannis::util::prop::check_n("fleet data-plane privacy invariant", 100, |rng| {
        let pool = 2 + rng.usize_below(3); // 2..=4 bays
        let n_jobs = 1 + rng.usize_below(2); // 1..=2 jobs
        let nets = ["mobilenet_v2", "squeezenet"];
        let mut fl = Fleet::new(FleetConfig {
            total_csds: pool,
            stage_io: false,
            ..Default::default()
        });
        let mut specs = Vec::new();
        for _ in 0..n_jobs {
            let spec = ExperimentConfig {
                network: nets[rng.usize_below(nets.len())].into(),
                num_csds: 1 + rng.usize_below(pool), // >= 1 so shards exist
                include_host: rng.bool(0.5),
                steps: 1 + rng.usize_below(6),
                ..Default::default()
            };
            fl.submit(spec.clone());
            specs.push(spec);
        }
        for _ in 0..1 + rng.usize_below(2) {
            fl.inject_degradation(
                SimTime::ns(rng.below(120_000_000_000)),
                rng.usize_below(pool),
                0.3 + 0.6 * rng.f64(),
            );
        }
        let report = fl.run().unwrap();
        total_retunes += report.retunes;
        total_transfers += fl.data_plane().transfers().len() as u64;
        // The shard maps were installed through the extent (bulk write)
        // path — the privacy audit below covers bulk I/O movement.
        assert!(
            fl.data_plane().stats().layout_pages > 0,
            "admission must stage shard maps onto flash"
        );
        // Audit the transfer ledger: every image that crossed nodes
        // must be public (JobId order is submission order).
        for t in fl.data_plane().transfers() {
            let d = Dataset::new(specs[t.job.0 as usize].dataset()).unwrap();
            match d.visibility(t.image).unwrap() {
                Visibility::Public => {}
                Visibility::Private { csd } => panic!(
                    "privacy violation: private image {} of csd{csd} crossed \
                     {} -> {} in {}",
                    t.image, t.from, t.to, t.job
                ),
            }
        }
    });
    assert!(total_retunes > 0, "the schedule must exercise rebalances");
    assert!(
        total_transfers > 0,
        "rebalances must produce cross-node movement somewhere in 100 fleets"
    );
}

/// Online-vs-batch equivalence (DESIGN.md §Runtime): a [`FleetRuntime`]
/// session with every job submitted at t = 0 and the fault schedule
/// replayed as external events — driven through *randomized*
/// `run_until` slices — is bit-identical to the legacy blocking
/// `Fleet::run()`: times, step counts, energy, link bytes, movement,
/// and the physical transfer ledger, under both executors. This is
/// what makes the session API a redesign rather than a fork: the batch
/// shape is literally one driving pattern of the runtime.
#[test]
fn online_session_is_bit_identical_to_batch_run() {
    stannis::util::prop::check_n("online-vs-batch equivalence", 12, |rng| {
        let pool = 2 + rng.usize_below(4); // 2..=5 bays
        let n_jobs = 1 + rng.usize_below(3); // 1..=3 jobs
        let nets = ["mobilenet_v2", "squeezenet", "nasnet", "inception_v3"];
        let specs: Vec<ExperimentConfig> = (0..n_jobs)
            .map(|_| {
                let num_csds = rng.usize_below(pool + 1);
                ExperimentConfig {
                    network: nets[rng.usize_below(nets.len())].into(),
                    num_csds,
                    include_host: num_csds == 0 || rng.bool(0.5),
                    steps: 1 + rng.usize_below(20),
                    ..Default::default()
                }
            })
            .collect();
        let faults: Vec<(u64, usize, f64)> = (0..rng.usize_below(3))
            .map(|_| {
                // Mix degradations and repairs (factor > 1).
                let factor = if rng.bool(0.3) {
                    1.2 + rng.f64()
                } else {
                    0.3 + 0.6 * rng.f64()
                };
                (rng.below(150_000_000_000), rng.usize_below(pool), factor)
            })
            .collect();
        // Random run_until boundaries the online session is sliced at —
        // the fast-forward must stop exactly at every one of them and
        // still produce the same totals.
        let mut slices: Vec<u64> =
            (0..rng.usize_below(5)).map(|_| rng.below(200_000_000_000)).collect();
        slices.sort_unstable();
        for ff in [true, false] {
            let cfg = || FleetConfig {
                total_csds: pool,
                stage_io: false,
                fast_forward: ff,
                // The per-job comparison below needs the online
                // session to keep its terminal jobs (the batch façade
                // always retains; the runtime default streams them out).
                retain_jobs: true,
                ..Default::default()
            };
            // Batch reference.
            let mut batch = Fleet::new(cfg());
            for s in &specs {
                batch.submit(s.clone());
            }
            for &(at_ns, device, factor) in &faults {
                batch.inject_degradation(SimTime::ns(at_ns), device, factor);
            }
            let br = batch.run().unwrap();
            let bt = batch.data_plane().transfers().to_vec();
            // Online session, sliced.
            let mut rt = FleetRuntime::new(cfg());
            for s in &specs {
                rt.submit_at(SimTime::ZERO, s.clone()).unwrap();
            }
            for &(at_ns, device, factor) in &faults {
                rt.inject_degradation(SimTime::ns(at_ns), device, factor);
            }
            for &s in &slices {
                rt.run_until(SimTime::ns(s)).unwrap();
            }
            rt.run_until_idle().unwrap();
            let or = rt.report();
            let ot = rt.data_plane().transfers().to_vec();
            assert_eq!(bt, ot, "transfer ledger must match (ff={ff})");
            assert_eq!(br.makespan, or.makespan, "makespan must match (ff={ff})");
            assert_eq!(br.total_images, or.total_images);
            assert_eq!(br.link_bytes, or.link_bytes);
            assert_eq!(br.retunes, or.retunes);
            assert_eq!(br.bytes_moved, or.bytes_moved);
            assert_eq!(br.total_energy_j.to_bits(), or.total_energy_j.to_bits());
            assert_eq!(br.overhead_energy_j.to_bits(), or.overhead_energy_j.to_bits());
            assert_eq!(br.jobs.len(), or.jobs.len());
            for (x, y) in br.jobs.iter().zip(&or.jobs) {
                assert_eq!(x.id, y.id);
                assert_eq!(x.state, y.state);
                assert_eq!(x.submitted_at, y.submitted_at);
                assert_eq!(x.admitted_at, y.admitted_at);
                assert_eq!(x.finished_at, y.finished_at);
                assert_eq!(x.steps_done, y.steps_done);
                assert_eq!(x.images, y.images);
                assert_eq!(x.link_bytes, y.link_bytes);
                assert_eq!(x.bytes_moved, y.bytes_moved);
                assert_eq!(x.lock_wait, y.lock_wait);
                assert_eq!(x.energy_j.to_bits(), y.energy_j.to_bits());
            }
        }
    });
}

/// A seeded workload trace end-to-end through the online session:
/// open-loop arrivals, a degrade-then-repair pair on one device, and
/// mid-run cancellations — with every cancelled job's shard pages
/// verifiably released (data-plane ledger == per-device FTL trims).
#[test]
fn workload_trace_with_cancel_and_repair_releases_shard_pages() {
    use stannis::config::{CancelSpec, FaultSpec, WeightedJob, WorkloadSpec};
    let spec = WorkloadSpec {
        total_csds: 4,
        stage_io: false,
        jobs: 2,
        mean_interarrival_secs: 0.0, // both arrive at t = 0
        mix: vec![WeightedJob {
            weight: 1.0,
            job: ExperimentConfig {
                network: "squeezenet".into(),
                num_csds: 2,
                include_host: false,
                steps: 100_000, // effectively endless: both end by cancel
                ..Default::default()
            },
        }],
        cancels: vec![
            CancelSpec { job: 0, at_secs: 50.0 },
            CancelSpec { job: 1, at_secs: 120.0 },
        ],
        faults: vec![
            FaultSpec { at_secs: 20.0, device: 0, factor: 0.5 },
            FaultSpec { at_secs: 40.0, device: 0, factor: 3.0 }, // repair, clamps to 1.0
        ],
        ..Default::default()
    };
    assert!(spec.faults[1].is_repair());
    let mut rt = FleetRuntime::new(FleetConfig {
        total_csds: spec.total_csds,
        stage_io: spec.stage_io,
        data_plane: spec.data_plane,
        fast_forward: spec.fast_forward,
        // This test inspects r.jobs[..] after the session drains.
        retain_jobs: true,
        ..Default::default()
    });
    // The single replay path the CLI and bench also use; ids are
    // assigned sequentially on a fresh runtime.
    let boundaries = rt.load_workload(&spec).unwrap();
    assert!(!boundaries.is_empty());
    let ids = [stannis::fleet::JobId(0), stannis::fleet::JobId(1)];
    // Drive to just before the first cancel and snapshot the pages the
    // teardown must free.
    rt.run_until(SimTime::secs(49)).unwrap();
    assert_eq!(rt.job_state(ids[0]), Some(JobState::Running));
    let resident0 = rt.data_plane().resident_pages(ids[0]);
    assert!(resident0 > 0, "job 0 must have staged shard pages");
    rt.run_until(SimTime::secs(119)).unwrap();
    let resident1 = rt.data_plane().resident_pages(ids[1]);
    assert!(resident1 > 0);
    rt.run_until_idle().unwrap();

    let r = rt.report();
    assert_eq!(r.cancelled, 2);
    let j0 = &r.jobs[0];
    assert_eq!(j0.state, JobState::Cancelled);
    assert_eq!(j0.finished_at, SimTime::secs(50));
    assert!(j0.steps_done > 0, "the cancel must land mid-run");
    assert_eq!(j0.retunes, 2, "degrade at 20s + repair at 40s");
    assert_eq!(r.jobs[1].state, JobState::Cancelled);
    assert_eq!(r.jobs[1].finished_at, SimTime::secs(120));
    assert!(r.jobs[1].steps_done > j0.steps_done, "job 1 ran 70s longer");
    assert_eq!(r.makespan, SimTime::secs(120), "the last cancel ends the session");

    // The ledger closes: all resident pages of both jobs were freed,
    // and the per-device FTL trim counters agree with the plane's
    // freed-page total.
    let stats = rt.data_plane().stats();
    assert_eq!(stats.cancels, 2);
    assert_eq!(stats.freed_pages, resident0 + resident1);
    assert_eq!(rt.data_plane().resident_pages(ids[0]), 0);
    assert_eq!(rt.data_plane().resident_pages(ids[1]), 0);
    let trims: u64 = (0..spec.total_csds)
        .map(|d| rt.pool().device(d).ftl_ref().stats().trims)
        .sum();
    assert_eq!(trims, stats.freed_pages);
}

/// The legacy per-step staged-IO executor (`stage_io` with the data
/// plane off — still reachable via `--no-data-plane`) keeps working:
/// flash staging runs per step through the FTL, fast-forward stays
/// inert (stateful staging), faults re-tune, and runs are
/// deterministic.
#[test]
fn legacy_staged_executor_still_runs() {
    let run = || {
        let mut fl = Fleet::new(FleetConfig {
            total_csds: 6,
            stage_io: true,
            data_plane: false,
            ..Default::default()
        });
        fl.submit(job("mobilenet_v2", 3, true, 5));
        fl.submit(job("squeezenet", 3, false, 5));
        fl.inject_degradation(SimTime::secs(20), 0, 0.7);
        fl.run().unwrap()
    };
    let (a, b) = (run(), run());
    assert_eq!(a.jobs[0].retunes, 1, "the 20s fault must land mid-run on job 0");
    assert_eq!(a.jobs[0].steps_done, 5);
    assert_eq!(a.jobs[1].steps_done, 5);
    assert!(a.jobs.iter().all(|j| j.bytes_moved == 0), "no data plane, no movement");
    assert!(a.jobs.iter().all(|j| j.lock_wait == SimTime::ZERO));
    // Per-step flash staging really happened (pages were read).
    assert!(a.jobs[0].energy_j > 0.0);
    assert_eq!(a.makespan, b.makespan, "legacy executor stays deterministic");
    for (x, y) in a.jobs.iter().zip(&b.jobs) {
        assert_eq!(x.finished_at, y.finished_at);
        assert_eq!(x.energy_j.to_bits(), y.energy_j.to_bits());
    }
}

/// Crash conservation (DESIGN.md §Crash-Recovery): across random
/// traces with scheduled bay crashes and random checkpoint cadences,
/// every root job's step budget is covered exactly once by its
/// crash-successor chain — checkpointed prefixes survive (always on an
/// interval boundary), the uncheckpointed tails are ledgered as lost
/// and redone — and the privacy invariant holds through every crash
/// re-layout: no private image ever crosses nodes, with the full audit
/// re-proving every component invariant after every event.
#[test]
fn property_crash_chains_conserve_steps_and_privacy() {
    use stannis::config::{CheckpointSpec, CrashSpec, WeightedJob, WorkloadSpec};
    use stannis::data::{Dataset, Visibility};
    use stannis::fleet::{runtime_for, JobId, RuntimeEvent};
    stannis::util::prop::check_n("crash conservation", 8, |rng| {
        const STEPS: usize = 12;
        let jobs = 2 + rng.usize_below(4);
        let interval = rng.usize_below(5) as u64; // 0 = checkpointing off
        let spec = WorkloadSpec {
            total_csds: 4,
            stage_io: false,
            retain_jobs: true,
            audit: true,
            seed: rng.below(1 << 32),
            jobs,
            mean_interarrival_secs: 3.0 + rng.f64() * 10.0,
            mix: vec![WeightedJob {
                weight: 1.0,
                job: ExperimentConfig {
                    network: "squeezenet".into(),
                    num_csds: 2,
                    include_host: false,
                    steps: STEPS,
                    ..Default::default()
                },
            }],
            crashes: (0..1 + rng.usize_below(3))
                .map(|_| CrashSpec { device: rng.usize_below(4), at_secs: rng.f64() * 120.0 })
                .collect(),
            checkpoint: CheckpointSpec {
                interval_steps: interval,
                host_copy: rng.bool(0.5),
            },
            ..Default::default()
        };
        let mut rt = runtime_for(&spec);
        rt.load_workload(&spec).expect("crash schedule replay");
        rt.run_until_idle().expect("trace drains through the crashes");
        let r = rt.report();
        let log = rt.take_log();

        // Successor chains from the log; every crash either kills one
        // tenant (and resubmits it) or lands on an idle bay.
        let mut next = std::collections::HashMap::new();
        let (mut crash_events, mut tenant_crashes) = (0usize, 0usize);
        for e in &log {
            if let RuntimeEvent::Crashed { job, successor, lost_steps, .. } = &e.event {
                crash_events += 1;
                match (job, successor) {
                    (Some(j), Some(s)) => {
                        tenant_crashes += 1;
                        next.insert(*j, (*s, *lost_steps));
                    }
                    (None, None) => {}
                    _ => panic!("a crash kills a tenant and resubmits it, or neither"),
                }
            }
        }
        assert_eq!(r.crashed, tenant_crashes);
        assert_eq!(
            r.devices_replaced, crash_events,
            "every crash swaps exactly one module (endurance is off)"
        );

        let find = |id: JobId| {
            r.jobs.iter().find(|j| j.id == id).expect("retained mode keeps every job")
        };
        let mut total_lost = 0usize;
        for root in 0..jobs {
            let mut id = JobId(root as u64);
            let mut covered = 0usize;
            let mut hops = 0usize;
            while let Some(&(succ, lost)) = next.get(&id) {
                let row = find(id);
                assert_eq!(row.state, JobState::Cancelled);
                assert!(row.crashed);
                assert_eq!(row.lost_steps, lost, "log and report must agree on the loss");
                assert!(row.steps_done >= lost);
                let credited = row.steps_done - lost;
                if interval > 0 {
                    assert_eq!(
                        credited as u64 % interval,
                        0,
                        "a surviving prefix always ends on a checkpoint boundary"
                    );
                } else {
                    assert_eq!(credited, 0, "no checkpoint, no surviving prefix");
                }
                covered += credited;
                total_lost += lost;
                id = succ;
                hops += 1;
                assert!(hops <= spec.crashes.len(), "chains are bounded by the schedule");
            }
            let last = find(id);
            assert_eq!(last.state, JobState::Completed, "every chain ends in completion");
            assert_eq!(last.lost_steps, 0);
            assert_eq!(
                covered + last.steps_done,
                STEPS,
                "root {root}: checkpointed prefixes + the final run must cover \
                 the spec exactly once"
            );
        }
        assert_eq!(r.lost_steps, total_lost);

        // Privacy survives crash re-layout: a successor's private shard
        // is laid out afresh through the replacement module's FTL, and
        // nothing private ever crossed nodes on the way (all jobs share
        // the single mix entry's dataset).
        let d = Dataset::new(spec.mix[0].job.dataset()).unwrap();
        for t in rt.data_plane().transfers() {
            match d.visibility(t.image).unwrap() {
                Visibility::Public => {}
                Visibility::Private { csd } => panic!(
                    "privacy violation: private image {} of csd{csd} crossed \
                     {} -> {} in {}",
                    t.image, t.from, t.to, t.job
                ),
            }
        }
    });
}

/// Determinism: the same submissions + fault schedule give identical
/// reports (the fleet inherits the sim core's guarantee).
#[test]
fn fleet_runs_are_deterministic() {
    let run = || {
        let mut fl = fleet(8, true);
        fl.submit(job("mobilenet_v2", 3, true, 4));
        fl.submit(job("inception_v3", 4, false, 4));
        fl.inject_degradation(SimTime::secs(20), 4, 0.7);
        fl.run().unwrap()
    };
    let (r1, r2) = (run(), run());
    assert_eq!(r1.makespan, r2.makespan);
    assert_eq!(r1.total_images, r2.total_images);
    assert_eq!(r1.link_bytes, r2.link_bytes);
    assert!((r1.total_energy_j - r2.total_energy_j).abs() < 1e-12);
    for (a, b) in r1.jobs.iter().zip(&r2.jobs) {
        assert_eq!(a.finished_at, b.finished_at);
        assert_eq!(a.images, b.images);
        assert_eq!(a.retunes, b.retunes);
    }
}
