//! Streaming-runtime and sweep-harness system tests (DESIGN.md
//! §Runtime, "Retirement & streaming" / "Sweep harness"):
//!
//! * The streaming default (terminal jobs retired out of the table,
//!   slots reused) is *bit-identical* to the retained-everything
//!   oracle — same log sequence, same retired records, same totals,
//!   same physical transfer ledger — under both executors.
//! * The chunked trace driver ([`run_trace_with`]) reproduces the
//!   all-upfront [`load_workload`] replay exactly.
//! * Multi-seed sweeps are invariant to worker count and seed order.
//! * The live-set high-water mark is bounded by concurrency, not by
//!   trace length — the property that makes million-arrival traces
//!   O(live jobs) in memory.
//!
//! [`load_workload`]: stannis::fleet::FleetRuntime::load_workload

use stannis::config::{
    CancelSpec, CheckpointSpec, EnduranceSpec, ExperimentConfig, FaultSpec, LinkFaultSpec,
    WeightedJob, WorkloadSpec,
};
use stannis::fleet::{
    run_sweep, run_trace, run_trace_with, runtime_for, FleetConfig, FleetReport, FleetRuntime,
    JobReport, RuntimeEvent, TransferRecord,
};
use stannis::sim::SimTime;

/// Everything one session run leaves behind, for cross-mode diffing.
struct RunOutcome {
    /// Debug-rendered log entries, in emission order (Debug output
    /// round-trips f64s, so equal strings mean equal bits).
    log: Vec<String>,
    /// Final reports carried by `Retired` records, in retirement order.
    retired: Vec<JobReport>,
    report: FleetReport,
    transfers: Vec<TransferRecord>,
    job_slots: usize,
}

/// The streaming default must be indistinguishable from the retained
/// oracle in everything except table residency: identical log
/// sequences (including the retired records, field for field),
/// identical retired-report streams, bit-identical totals and the
/// same physical transfer ledger — across random arrival/cancel/fault
/// schedules, random `run_until` slicing, and both executors.
#[test]
fn streaming_is_bit_identical_to_the_retained_oracle() {
    stannis::util::prop::check_n("streaming-vs-retained equivalence", 10, |rng| {
        let pool = 2 + rng.usize_below(4); // 2..=5 bays
        let n_jobs = 1 + rng.usize_below(3); // 1..=3 jobs
        let nets = ["mobilenet_v2", "squeezenet", "nasnet", "inception_v3"];
        let arrivals: Vec<(SimTime, ExperimentConfig)> = (0..n_jobs)
            .map(|_| {
                let num_csds = rng.usize_below(pool + 1);
                let spec = ExperimentConfig {
                    network: nets[rng.usize_below(nets.len())].into(),
                    num_csds,
                    include_host: num_csds == 0 || rng.bool(0.5),
                    steps: 1 + rng.usize_below(20),
                    ..Default::default()
                };
                (SimTime::ns(rng.below(60_000_000_000)), spec)
            })
            .collect();
        // Cancels aimed anywhere in the lifecycle: before arrival,
        // mid-run, or long after natural completion (a settled no-op —
        // in streaming mode the job is not even in the table anymore).
        let cancels: Vec<(usize, SimTime)> = (0..rng.usize_below(3))
            .map(|_| {
                let at = if rng.bool(0.3) {
                    SimTime::secs(500_000) // far beyond any completion
                } else {
                    SimTime::ns(rng.below(150_000_000_000))
                };
                (rng.usize_below(n_jobs), at)
            })
            .collect();
        let faults: Vec<(SimTime, usize, f64)> = (0..rng.usize_below(3))
            .map(|_| {
                let factor =
                    if rng.bool(0.3) { 1.2 + rng.f64() } else { 0.3 + 0.6 * rng.f64() };
                (SimTime::ns(rng.below(120_000_000_000)), rng.usize_below(pool), factor)
            })
            .collect();
        let mut slices: Vec<u64> =
            (0..rng.usize_below(4)).map(|_| rng.below(200_000_000_000)).collect();
        slices.sort_unstable();

        for ff in [true, false] {
            let run = |retain: bool| -> RunOutcome {
                let mut rt = FleetRuntime::new(FleetConfig {
                    total_csds: pool,
                    stage_io: false,
                    fast_forward: ff,
                    retain_jobs: retain,
                    ..Default::default()
                });
                let mut ids = Vec::new();
                for (at, s) in &arrivals {
                    ids.push(rt.submit_at(*at, s.clone()).unwrap());
                }
                for &(job_i, at) in &cancels {
                    rt.cancel(ids[job_i], at).unwrap();
                }
                for &(at, dev, factor) in &faults {
                    rt.inject_degradation(at, dev, factor);
                }
                // Random slicing, draining the log as a streaming
                // driver would — the concatenation must be invariant.
                let mut log = Vec::new();
                let mut retired = Vec::new();
                let mut drain = |rt: &mut FleetRuntime| {
                    for e in rt.take_log() {
                        if let RuntimeEvent::Retired { record } = &e.event {
                            retired.push(record.report.clone());
                        }
                        log.push(format!("{:?} {:?}", e.at, e.event));
                    }
                };
                for &s in &slices {
                    rt.run_until(SimTime::ns(s)).unwrap();
                    drain(&mut rt);
                }
                rt.run_until_idle().unwrap();
                drain(&mut rt);
                RunOutcome {
                    log,
                    retired,
                    report: rt.report(),
                    transfers: rt.data_plane().transfers().to_vec(),
                    job_slots: rt.job_slots(),
                }
            };
            let stream = run(false);
            let oracle = run(true);

            assert_eq!(stream.log, oracle.log, "log sequence must be mode-invariant (ff={ff})");
            assert_eq!(stream.retired, oracle.retired, "retired records must match (ff={ff})");
            assert_eq!(stream.transfers, oracle.transfers, "transfer ledger (ff={ff})");

            // The oracle's end-of-session per-job reports ARE the
            // retired records: `Job::report` is pure and terminal jobs
            // are never touched again.
            let (sr, or) = (&stream.report, &oracle.report);
            assert!(sr.jobs.is_empty(), "streaming table must be empty after drain (ff={ff})");
            assert_eq!(or.jobs.len(), or.retired, "oracle retains every retired job");
            for j in &or.jobs {
                let rec = oracle
                    .retired
                    .iter()
                    .find(|r| r.id == j.id)
                    .expect("every retained job has a retired record");
                assert_eq!(rec, j, "retired record vs end-of-session report for {}", j.id);
            }

            assert_eq!(sr.makespan, or.makespan);
            assert_eq!(sr.total_images, or.total_images);
            assert_eq!(sr.link_bytes, or.link_bytes);
            assert_eq!(sr.bytes_moved, or.bytes_moved);
            assert_eq!(sr.retunes, or.retunes);
            assert_eq!(sr.cancelled, or.cancelled);
            assert_eq!(sr.retired, or.retired);
            assert_eq!(sr.peak_live_jobs, or.peak_live_jobs);
            assert_eq!(sr.jobs_energy_j.to_bits(), or.jobs_energy_j.to_bits());
            assert_eq!(sr.total_energy_j.to_bits(), or.total_energy_j.to_bits());
            assert_eq!(sr.overhead_energy_j.to_bits(), or.overhead_energy_j.to_bits());
            assert_eq!(sr.queue_wait, or.queue_wait, "exact RunningStat equality (ff={ff})");
            assert_eq!(sr.lock_wait, or.lock_wait);

            // Residency is the one allowed difference.
            assert!(
                stream.job_slots <= oracle.job_slots,
                "streaming may never use more slots ({} vs {})",
                stream.job_slots,
                oracle.job_slots
            );
            assert!(
                stream.job_slots <= sr.peak_live_jobs,
                "streaming slots ({}) bounded by the concurrency high-water ({})",
                stream.job_slots,
                sr.peak_live_jobs
            );
        }
    });
}

fn trace_mix(steps: usize) -> Vec<WeightedJob> {
    vec![
        WeightedJob {
            weight: 3.0,
            job: ExperimentConfig {
                network: "mobilenet_v2".into(),
                num_csds: 2,
                include_host: false,
                steps,
                public_images: 256,
                private_per_csd: 64,
                ..Default::default()
            },
        },
        WeightedJob {
            weight: 1.0,
            job: ExperimentConfig {
                network: "squeezenet".into(),
                num_csds: 1,
                include_host: false,
                steps,
                public_images: 256,
                private_per_csd: 64,
                ..Default::default()
            },
        },
    ]
}

/// The chunked streaming driver replays a [`WorkloadSpec`] exactly
/// like the all-upfront `load_workload` path: same log, same totals,
/// to the bit — across random traces with cancels (including
/// post-completion ones) and degradation/repair pairs, under both
/// executors.
#[test]
fn chunked_driver_matches_the_upfront_replay() {
    stannis::util::prop::check_n("chunked-vs-upfront replay", 8, |rng| {
        for ff in [true, false] {
            let jobs = 3 + rng.usize_below(8);
            let cancels: Vec<CancelSpec> = (0..rng.usize_below(4))
                .map(|_| CancelSpec {
                    job: rng.usize_below(jobs),
                    at_secs: if rng.bool(0.25) {
                        1e6 // long after the trace drains: settled no-op
                    } else {
                        rng.f64() * 300.0
                    },
                })
                .collect();
            let faults: Vec<FaultSpec> = (0..rng.usize_below(3))
                .map(|_| FaultSpec {
                    at_secs: rng.f64() * 200.0,
                    device: rng.usize_below(5),
                    factor: if rng.bool(0.4) { 1.5 } else { 0.3 + 0.6 * rng.f64() },
                })
                .collect();
            let spec = WorkloadSpec {
                total_csds: 5,
                stage_io: false,
                fast_forward: ff,
                seed: rng.below(1 << 32),
                jobs,
                mean_interarrival_secs: 5.0 + rng.f64() * 30.0,
                mix: trace_mix(4 + rng.usize_below(6)),
                cancels,
                faults,
                ..Default::default()
            };

            let mut chunked_log = Vec::new();
            let (summary, rt) = run_trace_with(&spec, |e| {
                chunked_log.push(format!("{:?} {:?}", e.at, e.event));
            })
            .expect("chunked trace");

            let mut oracle = runtime_for(&spec);
            oracle.load_workload(&spec).expect("upfront replay");
            oracle.run_until_idle().expect("oracle drains");
            let oracle_log: Vec<String> =
                oracle.take_log().iter().map(|e| format!("{:?} {:?}", e.at, e.event)).collect();

            assert_eq!(chunked_log, oracle_log, "driver log must match the replay");
            let (cr, or) = (rt.report(), oracle.report());
            assert_eq!(cr.makespan, or.makespan);
            assert_eq!(cr.total_images, or.total_images);
            assert_eq!(cr.link_bytes, or.link_bytes);
            assert_eq!(cr.cancelled, or.cancelled);
            assert_eq!(cr.retired, or.retired);
            assert_eq!(cr.peak_live_jobs, or.peak_live_jobs);
            assert_eq!(cr.total_energy_j.to_bits(), or.total_energy_j.to_bits());
            assert_eq!(cr.queue_wait, or.queue_wait);
            assert_eq!(summary.jobs, jobs);
            assert_eq!(summary.completed + summary.cancelled, jobs);
        }
    });
}

/// Sweep determinism: the merged report is identical — every f64 to
/// the bit — whether the seeded traces run on 1, 2 or N workers, and
/// per-trace results do not depend on seed (shard) order.
#[test]
fn sweep_is_invariant_to_worker_count_and_shard_order() {
    stannis::util::prop::check_n("sweep worker invariance", 4, |rng| {
        let base = WorkloadSpec {
            total_csds: 5,
            stage_io: false,
            seed: rng.below(1 << 32),
            jobs: 4 + rng.usize_below(6),
            mean_interarrival_secs: 4.0 + rng.f64() * 20.0,
            mix: trace_mix(5),
            cancels: vec![CancelSpec { job: 1, at_secs: rng.f64() * 120.0 }],
            ..Default::default()
        };
        let n_seeds = 2 + rng.usize_below(4);
        let seeds: Vec<u64> = (0..n_seeds).map(|_| rng.below(1 << 20)).collect();

        let one = run_sweep(&base, &seeds, 1).expect("1 worker");
        let two = run_sweep(&base, &seeds, 2).expect("2 workers");
        let n = run_sweep(&base, &seeds, n_seeds).expect("N workers");
        let over = run_sweep(&base, &seeds, 5 * n_seeds).expect("over-provisioned workers");
        assert_eq!(one, two, "1 vs 2 workers");
        assert_eq!(one, n, "1 vs N workers");
        assert_eq!(one, over, "worker count clamps");

        // Shard order: reversing the seed list permutes the traces but
        // cannot change any per-seed result.
        let mut rev_seeds = seeds.clone();
        rev_seeds.reverse();
        let rev = run_sweep(&base, &rev_seeds, 2).expect("reversed seeds");
        assert_eq!(rev.total_jobs, one.total_jobs);
        assert_eq!(rev.total_images, one.total_images);
        assert_eq!(rev.cancelled, one.cancelled);
        assert_eq!(rev.peak_live_jobs, one.peak_live_jobs);
        for t in &one.traces {
            let r = rev
                .traces
                .iter()
                .find(|r| r.seed == t.seed)
                .expect("every seed appears once in the reversed sweep");
            assert_eq!(r, t, "per-seed summary must not depend on shard order");
        }
    });
}

/// The regression the tentpole exists for: on a cancel/complete-heavy
/// trace the live set — and therefore the streaming job table — stays
/// bounded by the admission concurrency limit, while the retained
/// oracle's table grows with every arrival. Slots are reused: hundreds
/// of jobs pass through a table that never exceeds a handful of slots.
#[test]
fn live_set_high_water_is_bounded_by_concurrency_not_trace_length() {
    const JOBS: usize = 600;
    // Pool of 4, 2 CSDs per job, no host: at most 2 jobs run at once.
    const MAX_CONCURRENT: usize = 2;
    let spec = WorkloadSpec {
        total_csds: 4,
        stage_io: false,
        seed: 29,
        jobs: JOBS,
        mean_interarrival_secs: 3.0,
        mix: vec![WeightedJob {
            weight: 1.0,
            job: ExperimentConfig {
                network: "mobilenet_v2".into(),
                num_csds: 2,
                include_host: false,
                steps: 5,
                public_images: 128,
                private_per_csd: 32,
                ..Default::default()
            },
        }],
        // Every third job is torn down early — heavy slot churn.
        cancels: (0..JOBS)
            .step_by(3)
            .map(|i| CancelSpec { job: i, at_secs: 1.0 + 3.0 * i as f64 })
            .collect(),
        ..Default::default()
    };

    let summary = run_trace(&spec).expect("streaming trace");
    assert_eq!(summary.completed + summary.cancelled, JOBS);
    assert!(summary.cancelled >= JOBS / 6, "the cancel schedule must actually fire");
    assert!(
        summary.peak_live_jobs <= MAX_CONCURRENT,
        "peak live jobs {} must be bounded by concurrency {}",
        summary.peak_live_jobs,
        MAX_CONCURRENT
    );
    assert!(
        summary.job_slots <= MAX_CONCURRENT,
        "streaming table grew {} slots for {} arrivals — slots are not being reused",
        summary.job_slots,
        JOBS
    );

    // The retained oracle on the same trace materializes every arrival.
    let mut oracle_spec = spec.clone();
    oracle_spec.retain_jobs = true;
    let oracle = run_trace(&oracle_spec).expect("retained trace");
    assert_eq!(oracle.job_slots, JOBS, "the oracle keeps every job ever submitted");
    assert_eq!(oracle.peak_live_jobs, summary.peak_live_jobs);
    assert_eq!(oracle.total_images, summary.total_images);
    assert_eq!(oracle.jobs_energy_j.to_bits(), summary.jobs_energy_j.to_bits());
}

/// Satellite edge cases on the [`WorkloadSpec`] path: a cancel landing
/// after natural completion is a no-op (no panic, no double release,
/// no timeline stretch), and a zero-weight mix entry fails validation
/// with an error naming the offending entry.
#[test]
fn workload_spec_edge_cases() {
    // Cancel far beyond the last completion: the job has retired and
    // left the table; the event must settle as a no-op.
    let mut spec = WorkloadSpec {
        total_csds: 4,
        stage_io: false,
        seed: 5,
        jobs: 3,
        mean_interarrival_secs: 2.0,
        mix: trace_mix(4),
        cancels: vec![
            CancelSpec { job: 0, at_secs: 9.0e5 },
            CancelSpec { job: 0, at_secs: 9.5e5 }, // second no-op on the same job
        ],
        ..Default::default()
    };
    let summary = run_trace(&spec).expect("late cancels are no-ops");
    assert_eq!(summary.cancelled, 0, "post-completion cancels must not cancel anything");
    assert_eq!(summary.completed, 3);
    assert!(
        summary.makespan < SimTime::secs(800_000),
        "a settled cancel must not stretch the timeline to its firing instant"
    );

    // Cancel referencing a job index beyond the trace fails up front.
    spec.cancels = vec![CancelSpec { job: 7, at_secs: 1.0 }];
    let err = run_trace(&spec).unwrap_err().to_string();
    assert!(err.contains("references job 7"), "got: {err}");
    assert!(err.contains("cancel entry 0"), "must name the entry, got: {err}");

    // Zero-weight mix entry: rejected with the entry named.
    spec.cancels.clear();
    spec.mix[1].weight = 0.0;
    let err = spec.validate().unwrap_err().to_string();
    assert!(err.contains("mix entry 1"), "must name the offending entry, got: {err}");
    assert!(err.contains("weight"), "must explain the weight rule, got: {err}");
    let err = run_trace(&spec).unwrap_err().to_string();
    assert!(err.contains("mix entry 1"), "the trace driver must validate too, got: {err}");

    // Negative and non-finite weights fall under the same rule.
    spec.mix[1].weight = -2.0;
    assert!(spec.validate().is_err());
    spec.mix[1].weight = f64::NAN;
    assert!(spec.validate().is_err());
}

/// Endurance knobs that cannot fire must be invisible (DESIGN.md
/// §Endurance, determinism contract): a pool whose blocks never reach
/// their P/E limit (`pe_limit = u32::MAX`) and whose retry ladder is
/// never climbed produces the *bit-identical* trace — same log, same
/// totals, same wear-independent summary — as the endurance-off
/// default, across random arrival/cancel/fault schedules and both
/// executors. This pins the EOL pipeline's hot-path cost to zero
/// observable effect until a block actually retires.
#[test]
fn unreachable_endurance_limits_are_bit_identical_to_endurance_off() {
    stannis::util::prop::check_n("endurance-off bit identity", 6, |rng| {
        for ff in [true, false] {
            let jobs = 2 + rng.usize_below(6);
            let base = WorkloadSpec {
                total_csds: 4,
                stage_io: false,
                fast_forward: ff,
                seed: rng.below(1 << 32),
                jobs,
                mean_interarrival_secs: 4.0 + rng.f64() * 20.0,
                mix: trace_mix(3 + rng.usize_below(5)),
                cancels: (0..rng.usize_below(2))
                    .map(|_| CancelSpec { job: rng.usize_below(jobs), at_secs: rng.f64() * 200.0 })
                    .collect(),
                faults: (0..rng.usize_below(2))
                    .map(|_| FaultSpec {
                        at_secs: rng.f64() * 150.0,
                        device: rng.usize_below(4),
                        factor: 0.4 + 0.5 * rng.f64(),
                    })
                    .collect(),
                ..Default::default()
            };
            let mut armed = base.clone();
            armed.endurance = EnduranceSpec {
                pe_limit: u32::MAX,
                read_retries: 0,
                retry_step_us: 100.0,
            };

            let mut off_log = Vec::new();
            let (off, off_rt) = run_trace_with(&base, |e| {
                off_log.push(format!("{:?} {:?}", e.at, e.event));
            })
            .expect("endurance-off trace");
            let mut on_log = Vec::new();
            let (on, on_rt) = run_trace_with(&armed, |e| {
                on_log.push(format!("{:?} {:?}", e.at, e.event));
            })
            .expect("unreachable-limit trace");

            assert_eq!(off_log, on_log, "log streams must match to the bit");
            assert_eq!(off, on, "trace summaries must match to the bit");
            let (a, b) = (off_rt.report(), on_rt.report());
            assert_eq!(a.makespan, b.makespan);
            assert_eq!(a.total_energy_j.to_bits(), b.total_energy_j.to_bits());
            assert_eq!(a.wear, b.wear, "wear counters are observational, not behavioral");
            assert_eq!(a.ecc, b.ecc);
            assert_eq!(b.drained, 0, "nothing can drain below an unreachable limit");
            assert_eq!(b.devices_replaced, 0);
        }
    });
}

/// Crash-pipeline knobs that cannot fire must be invisible (DESIGN.md
/// §Crash-Recovery, determinism contract): no crash schedule, a
/// checkpoint interval no trace can reach, and a retry ladder whose
/// per-attempt failure probability is effectively zero produce the
/// *bit-identical* trace — same log stream, same summary, same report,
/// same energy bits, same state fingerprint — as the all-defaults-off
/// run, across random schedules, both executors, and random
/// `run_until` slicings of the armed session. An armed ladder also
/// disarms the fast-forward (per-send RNG draws are stateful), so this
/// doubles as an executor-equivalence check for the armed path.
#[test]
fn unreachable_crash_pipeline_knobs_are_bit_identical_to_off() {
    stannis::util::prop::check_n("crash-pipeline-off bit identity", 6, |rng| {
        for ff in [true, false] {
            let jobs = 2 + rng.usize_below(5);
            let base = WorkloadSpec {
                total_csds: 4,
                stage_io: false,
                fast_forward: ff,
                seed: rng.below(1 << 32),
                jobs,
                mean_interarrival_secs: 4.0 + rng.f64() * 20.0,
                mix: trace_mix(3 + rng.usize_below(5)),
                cancels: (0..rng.usize_below(2))
                    .map(|_| CancelSpec { job: rng.usize_below(jobs), at_secs: rng.f64() * 200.0 })
                    .collect(),
                faults: (0..rng.usize_below(2))
                    .map(|_| FaultSpec {
                        at_secs: rng.f64() * 150.0,
                        device: rng.usize_below(4),
                        factor: 0.4 + 0.5 * rng.f64(),
                    })
                    .collect(),
                ..Default::default()
            };
            let mut armed = base.clone();
            armed.checkpoint =
                CheckpointSpec { interval_steps: 1 << 40, host_copy: true };
            armed.link_fault =
                LinkFaultSpec { fail_prob: 1e-300, ..Default::default() };

            let mut off_log = Vec::new();
            let (off, off_rt) = run_trace_with(&base, |e| {
                off_log.push(format!("{:?} {:?}", e.at, e.event));
            })
            .expect("crash-pipeline-off trace");
            let mut on_log = Vec::new();
            let (on, on_rt) = run_trace_with(&armed, |e| {
                on_log.push(format!("{:?} {:?}", e.at, e.event));
            })
            .expect("unreachable-knobs trace");

            assert_eq!(off_log, on_log, "log streams must match to the bit");
            assert_eq!(off, on, "trace summaries must match to the bit");
            assert_eq!(
                off_rt.fingerprint(),
                on_rt.fingerprint(),
                "state fingerprints must match"
            );
            let (a, b) = (off_rt.report(), on_rt.report());
            assert_eq!(a.makespan, b.makespan);
            assert_eq!(a.total_energy_j.to_bits(), b.total_energy_j.to_bits());
            assert_eq!(b.crashed, 0);
            assert_eq!(b.lost_steps, 0);
            assert_eq!(b.checkpoint_bytes, 0, "an unreachable interval never writes");
            assert_eq!(b.link_retries, 0, "a ~0 failure rate never climbs the ladder");
            assert_eq!(b.devices_replaced, 0);

            // The armed session sliced at random instants lands on the
            // same final state (the fingerprint is slicing-invariant).
            let mut cuts: Vec<u64> =
                (0..rng.usize_below(4)).map(|_| rng.below(300_000_000_000)).collect();
            cuts.sort_unstable();
            let mut sliced = runtime_for(&armed);
            sliced.load_workload(&armed).expect("armed replay");
            for &c in &cuts {
                sliced.run_until(SimTime::ns(c)).expect("armed slice");
            }
            sliced.run_until_idle().expect("armed drain");
            assert_eq!(
                sliced.fingerprint(),
                on_rt.fingerprint(),
                "the armed fingerprint must be run_until-slicing-invariant"
            );
        }
    });
}
