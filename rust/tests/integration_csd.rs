//! Integration: the CSD substrate composed end-to-end — dataset pages
//! through FTL/flash, both data paths, the modeled scheduler over real
//! device state, and cross-module invariants.

use stannis::coordinator::{ScheduleConfig, Scheduler};
use stannis::csd::{CsdConfig, FlashConfig, FtlConfig, NewportCsd};
use stannis::perfmodel::PerfModel;
use stannis::sim::SimTime;
use stannis::tunnel::TunnelConfig;

fn small_csd_cfg() -> CsdConfig {
    CsdConfig {
        ftl: FtlConfig {
            flash: FlashConfig {
                channels: 4,
                dies_per_channel: 2,
                blocks_per_die: 32,
                pages_per_block: 16,
                page_bytes: 4096,
                ..Default::default()
            },
            overprovision: 0.2,
            gc_low_water: 4,
            gc_high_water: 8,
            ..Default::default()
        },
        ..Default::default()
    }
}

#[test]
fn dataset_epoch_through_flash_preserves_tags() {
    // Write a full "dataset" (one image per page), then run three
    // epochs of batched ISP reads; tags must always match, GC or not.
    let mut csd = NewportCsd::new(0, small_csd_cfg(), 11);
    let images = 512u32;
    for lpn in 0..images {
        csd.write_page(lpn, 0xAA00_0000 | lpn as u64, SimTime::ZERO).unwrap();
    }
    let mut now = SimTime::ZERO;
    for _epoch in 0..3 {
        for batch_start in (0..images).step_by(16) {
            let lpns: Vec<u32> = (batch_start..batch_start + 16).collect();
            now = csd.read_for_isp(&lpns, now).unwrap();
        }
    }
    for lpn in (0..images).step_by(37) {
        let r = csd.ftl().read(lpn, now).unwrap();
        assert_eq!(r.tag, 0xAA00_0000 | lpn as u64);
    }
    assert_eq!(csd.io_stats().isp_path_reads as u32, 3 * images);
}

#[test]
fn training_interleaved_with_writes_and_gc() {
    // Ingest (writes) runs while the ISP trains — the paper's
    // always-on storage claim. Everything must stay consistent.
    let mut csd = NewportCsd::new(0, small_csd_cfg(), 13);
    let logical = csd.ftl_ref().logical_pages() as u32;
    for lpn in 0..logical {
        csd.write_page(lpn, lpn as u64, SimTime::ZERO).unwrap();
    }
    let mut now = SimTime::ZERO;
    for round in 0..6u64 {
        // Ingest: overwrite a third of the space (forces GC pressure).
        for lpn in (0..logical).step_by(3) {
            csd.write_page(lpn, (round << 32) | lpn as u64, now).unwrap();
        }
        // Train: stage a batch + compute.
        let lpns: Vec<u32> = (1..65).collect();
        now = csd
            .isp_train_step(&lpns, SimTime::secs(1), 14_000_000, 500_000, 16, now)
            .unwrap();
    }
    csd.ftl_ref().check_invariants().unwrap();
    assert!(csd.ftl_ref().stats().gc_runs > 0, "GC should have run under this churn");
    // Latest data visible.
    let r = csd.ftl().read(3, now).unwrap();
    assert_eq!(r.tag, (5 << 32) | 3);
}

#[test]
fn modeled_schedule_over_real_devices_accounts_io() {
    let mut sched = Scheduler::new(
        PerfModel::default(),
        3,
        TunnelConfig::default(),
        small_csd_cfg(),
    );
    sched.preload_data(64).unwrap();
    let r = sched
        .run(&ScheduleConfig {
            network: "mobilenet_v2".into(),
            num_csds: 3,
            include_host: true,
            bs_csd: 8,
            bs_host: 32,
            steps: 4,
            image_bytes: 4096,
            stage_io: true,
            per_step: false,
        })
        .unwrap();
    assert!(r.flash_reads > 0);
    assert!(r.link_bytes > 0);
    assert!(r.images_per_sec > 0.0);
    assert!(r.elapsed > SimTime::ZERO);
    // 4 steps * (32 host + 3*8 csd) images
    let expected = 4 * (32 + 24);
    let images = (r.images_per_sec * r.elapsed.as_secs_f64()).round() as usize;
    assert_eq!(images, expected);
}

#[test]
fn isp_advantage_grows_under_link_contention() {
    // The §III claim quantified: gradient sync on the PCIe link delays
    // host-path staging but not ISP-path staging.
    let stage = |contended: bool| {
        let mut csd = NewportCsd::new(0, small_csd_cfg(), 17);
        for lpn in 0..256u32 {
            csd.write_page(lpn, 0, SimTime::ZERO).unwrap();
        }
        let t0 = SimTime::secs(5);
        if contended {
            csd.tunnel_transfer(13_880_000, t0);
        }
        let lpns: Vec<u32> = (0..64).collect();
        let host = csd.read_for_host(&lpns, t0).unwrap() - t0;
        let mut csd2 = NewportCsd::new(0, small_csd_cfg(), 17);
        for lpn in 0..256u32 {
            csd2.write_page(lpn, 0, SimTime::ZERO).unwrap();
        }
        if contended {
            csd2.tunnel_transfer(13_880_000, t0);
        }
        let isp = csd2.read_for_isp(&lpns, t0).unwrap() - t0;
        host.as_ns() as f64 / isp.as_ns() as f64
    };
    let idle = stage(false);
    let contended = stage(true);
    assert!(idle > 1.0, "ISP path must win even on an idle link: {idle}");
    assert!(contended > idle, "contention must widen the gap: {idle} -> {contended}");
}

#[test]
fn ecc_failures_surface_as_errors_at_extreme_wear() {
    use stannis::csd::{EccConfig, Ftl};
    let cfg = FtlConfig {
        flash: FlashConfig {
            channels: 2,
            dies_per_channel: 1,
            blocks_per_die: 16,
            pages_per_block: 8,
            page_bytes: 16384,
            ..Default::default()
        },
        // Brutal wear-out model so uncorrectables appear quickly.
        ecc: EccConfig { rber_per_pe: 5e-4, t: 8, ..Default::default() },
        overprovision: 0.25,
        gc_low_water: 2,
        gc_high_water: 4,
        ..Default::default()
    };
    let mut ftl = Ftl::new(cfg, 23);
    let n = ftl.logical_pages() as u32;
    // Hammer the device until blocks accumulate hundreds of P/E cycles.
    let mut failed = false;
    'outer: for round in 0..400u64 {
        for lpn in 0..n {
            if ftl.write(lpn, round, SimTime::ZERO).is_err() {
                failed = true;
                break 'outer;
            }
        }
        for lpn in (0..n).step_by(5) {
            if ftl.read(lpn, SimTime::ZERO).is_err() {
                failed = true; // uncorrectable ECC error propagated
                break 'outer;
            }
        }
    }
    assert!(
        failed || ftl.max_pe_cycles() > 100,
        "either an uncorrectable surfaced or the device absorbed heavy wear"
    );
}
