//! Fault injection: degraded devices, dropped workers, link slowdowns —
//! the coordinator must stay correct (never silently wrong) and the
//! models must respond in the physically sensible direction.

use stannis::allreduce::{ring_allreduce_mean, ring_time};
use stannis::coordinator::{balance, modeled_throughput, tune, TuneConfig};
use stannis::data::{Dataset, DatasetConfig};
use stannis::perfmodel::PerfModel;
use stannis::sim::SimTime;
use stannis::tunnel::{NodeId, Tunnel, TunnelConfig};

#[test]
fn degraded_newport_gets_smaller_batch_and_less_work() {
    // A CSD running at 60% (thermal throttle) must be assigned a
    // proportionally lighter schedule by Algorithm 1.
    let cfg = TuneConfig::default();
    let mut healthy = PerfModel::default();
    let mut degraded = PerfModel::with_scales(1.0, 0.6);
    let h = tune(&mut healthy, "mobilenet_v2", &cfg).unwrap();
    let d = tune(&mut degraded, "mobilenet_v2", &cfg).unwrap();
    assert!(d.newport_ips < h.newport_ips * 0.7);
    // Same newport batch (saturation point doesn't move) but the host
    // target time grows, so the host batch grows to compensate.
    assert!(d.host_bs > h.host_bs, "{} !> {}", d.host_bs, h.host_bs);
}

#[test]
fn slow_tunnel_hurts_big_models_most() {
    // Cut tunnel sw bandwidth 4x: InceptionV3 (23.8M params) must lose
    // a larger fraction of its throughput than SqueezeNet (1.25M).
    let loss_frac = |net: &str, bs_csd: usize, bs_host: usize| {
        let fast = modeled_throughput(net, 12, true, bs_csd, bs_host, 3)
            .unwrap()
            .images_per_sec;
        // Degrade via a custom scheduler run.
        let mut sched = stannis::coordinator::Scheduler::new(
            PerfModel::default(),
            12,
            TunnelConfig { sw_bw_csd: 20.0e6, ..Default::default() },
            stannis::csd::CsdConfig::default(),
        );
        let slow = sched
            .run(&stannis::coordinator::ScheduleConfig {
                network: net.into(),
                num_csds: 12,
                include_host: true,
                bs_csd,
                bs_host,
                steps: 3,
                image_bytes: 12 * 1024,
                stage_io: false,
                per_step: false,
            })
            .unwrap()
            .images_per_sec;
        1.0 - slow / fast
    };
    let inc = loss_frac("inception_v3", 16, 370);
    let sq = loss_frac("squeezenet", 50, 850);
    assert!(
        inc > sq + 0.05,
        "inception must suffer more from a slow tunnel: {inc:.3} vs {sq:.3}"
    );
}

#[test]
fn worker_dropout_mid_allreduce_is_consistent() {
    // A worker dies between steps: the remaining replicas re-form the
    // ring and still compute an exact mean among themselves.
    let mut replicas: Vec<Vec<f32>> = (0..5).map(|w| vec![w as f32; 100]).collect();
    ring_allreduce_mean(&mut replicas).unwrap();
    assert!(replicas.iter().all(|r| (r[0] - 2.0).abs() < 1e-6));
    // Drop worker 3, next step re-rings with 4.
    replicas.remove(3);
    for (w, r) in replicas.iter_mut().enumerate() {
        r.iter_mut().for_each(|x| *x = (w * w) as f32);
    }
    ring_allreduce_mean(&mut replicas).unwrap();
    let want = (0 + 1 + 4 + 9) as f32 / 4.0;
    assert!(replicas.iter().all(|r| (r[0] - want).abs() < 1e-5));
}

#[test]
fn ring_time_degrades_gracefully_with_slow_endpoints() {
    let bytes = 13_880_000;
    let ranks: Vec<NodeId> = std::iter::once(NodeId::Host).chain((0..8).map(NodeId::Csd)).collect();
    let mut fast = Tunnel::new(8, TunnelConfig::default());
    let t_fast = ring_time(&mut fast, &ranks, bytes, SimTime::ZERO);
    let mut slow = Tunnel::new(8, TunnelConfig { sw_bw_csd: 20.0e6, ..Default::default() });
    let t_slow = ring_time(&mut slow, &ranks, bytes, SimTime::ZERO);
    let ratio = t_slow.as_secs_f64() / t_fast.as_secs_f64();
    assert!(
        (2.0..6.0).contains(&ratio),
        "4x endpoint slowdown should cost ~4x sync, got {ratio:.2}"
    );
}

#[test]
fn empty_private_shard_with_dry_pool_is_rejected() {
    // A CSD with no private data and no public budget cannot be given
    // work out of thin air — must be an error, not silent starvation.
    let d = Dataset::new(DatasetConfig {
        public_images: 1, // pool effectively dry
        private_per_csd: vec![64, 0],
        ..Default::default()
    })
    .unwrap();
    assert!(balance(&d, 2, 8, 32, true).is_err());
}

#[test]
fn dataset_visibility_never_panics_at_boundaries() {
    let d = Dataset::new(DatasetConfig {
        public_images: 10,
        private_per_csd: vec![5],
        ..Default::default()
    })
    .unwrap();
    assert!(d.visibility(0).is_ok());
    assert!(d.visibility(14).is_ok());
    assert!(d.visibility(15).is_err());
    assert!(d.image(15).is_err());
}
