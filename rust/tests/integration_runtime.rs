//! Integration: AOT artifacts load, compile and execute through PJRT,
//! and the numerics behave like training (finite grads, loss ↓).
//!
//! Requires `make artifacts` to have run; environments without the
//! AOT toolchain (no `artifacts/manifest.json` anywhere above the
//! cwd) skip with a note instead of failing the tier-1 suite.

use stannis::model::{ParamStore, Sgd, SgdConfig, Tensor};
use stannis::runtime::{default_artifacts_dir, Engine};

// One engine for the whole file (xla's client is Rc-based/!Send, and
// artifact compilation is the dominant cost).

fn synth_batch(hw: usize, bs: usize, classes: usize, seed: u64) -> (Tensor, Vec<i32>) {
    let images = Tensor::randn(vec![bs, hw, hw, 3], 1.0, seed);
    let labels: Vec<i32> = (0..bs).map(|i| ((seed as usize + i * 7) % classes) as i32).collect();
    (images, labels)
}

fn init_params_match_manifest(eng: &Engine) {
    let net = eng.network("mobilenet_v2_s").unwrap().clone();
    let params = eng.init_params("mobilenet_v2_s", 0).unwrap();
    params.check_specs(&net.params).unwrap();
    assert_eq!(params.num_scalars(), net.param_count);
    assert!(params.is_finite());
    // seeds differ -> replicas differ
    let params1 = eng.init_params("mobilenet_v2_s", 1).unwrap();
    assert!(params.max_abs_diff(&params1) > 1e-3);
    // same seed -> identical replica (determinism)
    let params0 = eng.init_params("mobilenet_v2_s", 0).unwrap();
    assert_eq!(params.max_abs_diff(&params0), 0.0);
}

fn train_step_returns_finite_grads(eng: &Engine) {
    let net = eng.network("mobilenet_v2_s").unwrap().clone();
    let params = eng.init_params("mobilenet_v2_s", 42).unwrap();
    let (x, y) = synth_batch(net.input_hw, 8, net.num_classes, 3);
    let out = eng.train_step("mobilenet_v2_s", 8, &params, &x, &y).unwrap();
    assert!(out.loss.is_finite() && out.loss > 0.0, "loss={}", out.loss);
    assert!(out.grads.is_finite());
    assert_eq!(out.grads.len(), params.len());
    // gradient must be non-trivial
    assert!(out.grads.to_flat().iter().any(|g| g.abs() > 1e-8));
}

fn loss_decreases_under_sgd(eng: &Engine) {
    let net = eng.network("mobilenet_v2_s").unwrap().clone();
    let mut params = eng.init_params("mobilenet_v2_s", 7).unwrap();
    let (x, y) = synth_batch(net.input_hw, 16, net.num_classes, 11);
    let mut opt = Sgd::new(SgdConfig { base_lr: 0.02, momentum: 0.9, ..Default::default() });

    let first = eng.train_step("mobilenet_v2_s", 16, &params, &x, &y).unwrap().loss;
    for _ in 0..15 {
        let out = eng.train_step("mobilenet_v2_s", 16, &params, &x, &y).unwrap();
        opt.apply(&mut params, &out.grads).unwrap();
    }
    let last = eng.train_step("mobilenet_v2_s", 16, &params, &x, &y).unwrap().loss;
    assert!(
        last < first * 0.7,
        "memorizing one batch must cut loss sharply: {first} -> {last}"
    );
}

fn wrong_batch_size_is_an_error(eng: &Engine) {
    let net = eng.network("mobilenet_v2_s").unwrap().clone();
    let params = eng.init_params("mobilenet_v2_s", 0).unwrap();
    let (x, y) = synth_batch(net.input_hw, 3, net.num_classes, 0);
    assert!(eng.train_step("mobilenet_v2_s", 3, &params, &x, &y).is_err());
}

fn wrong_image_shape_is_an_error(eng: &Engine) {
    let net = eng.network("mobilenet_v2_s").unwrap().clone();
    let params = eng.init_params("mobilenet_v2_s", 0).unwrap();
    let x = Tensor::randn(vec![8, net.input_hw + 1, net.input_hw, 3], 1.0, 0);
    let y = vec![0i32; 8];
    assert!(eng.train_step("mobilenet_v2_s", 8, &params, &x, &y).is_err());
}

fn eval_step_counts_correct(eng: &Engine) {
    let net = eng.network("mobilenet_v2_s").unwrap().clone();
    let params = eng.init_params("mobilenet_v2_s", 0).unwrap();
    let bs = net.eval_batch_size;
    let (x, y) = synth_batch(net.input_hw, bs, net.num_classes, 5);
    let out = eng.eval_step("mobilenet_v2_s", &params, &x, &y).unwrap();
    assert!(out.loss.is_finite());
    assert!(out.correct >= 0 && out.correct <= bs as i32);
}

fn replicas_with_same_inputs_get_same_grads(eng: &Engine) {
    // Determinism across executions — the property that lets one PJRT
    // client stand in for N physical workers (DESIGN.md §2).
    let net = eng.network("mobilenet_v2_s").unwrap().clone();
    let params = eng.init_params("mobilenet_v2_s", 9).unwrap();
    let (x, y) = synth_batch(net.input_hw, 4, net.num_classes, 13);
    let a = eng.train_step("mobilenet_v2_s", 4, &params, &x, &y).unwrap();
    let b = eng.train_step("mobilenet_v2_s", 4, &params, &x, &y).unwrap();
    assert_eq!(a.loss, b.loss);
    assert_eq!(a.grads.max_abs_diff(&b.grads), 0.0);
}

#[test]
fn runtime_suite() {
    let dir = default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping runtime_suite: no AOT artifacts (run `make artifacts`)");
        return;
    }
    let eng = Engine::new(dir).expect("run `make artifacts` first");
    init_params_match_manifest(&eng);
    train_step_returns_finite_grads(&eng);
    loss_decreases_under_sgd(&eng);
    wrong_batch_size_is_an_error(&eng);
    wrong_image_shape_is_an_error(&eng);
    eval_step_counts_correct(&eng);
    replicas_with_same_inputs_get_same_grads(&eng);
}
