"""L2 tests: the four scaled networks + training-step semantics.

Checks the AOT contract (spec order/shapes/param counts), numerical
health (finite grads, descending loss) and the init/eval entry points
for every network that gets lowered to artifacts.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.model import (
    build_model,
    cross_entropy,
    init_params,
    make_eval_step,
    make_init_fn,
    make_train_step,
    spec_dicts,
)
from compile.models import ALIASES, MODEL_NAMES


@pytest.fixture(scope="module", params=MODEL_NAMES)
def model(request):
    return build_model(request.param)


def batch(model, bs=4, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(bs, model.input_hw, model.input_hw, 3)).astype("float32"))
    y = jnp.asarray(rng.integers(0, model.num_classes, size=(bs,)).astype("int32"))
    return x, y


class TestStructure:
    def test_param_specs_consistent(self, model):
        specs = spec_dicts(model)
        assert len(specs) == len(model.net.specs)
        total = sum(int(np.prod(s["shape"])) for s in specs)
        assert total == model.net.param_count
        names = [s["name"] for s in specs]
        assert len(names) == len(set(names)), "param names must be unique"

    def test_costs_positive(self, model):
        assert model.net.macs > 0
        assert model.net.flops == 2 * model.net.macs

    def test_forward_shape(self, model):
        params = init_params(model, 0)
        x, _ = batch(model)
        logits = model.apply(params, x)
        assert logits.shape == (4, model.num_classes)
        assert bool(jnp.isfinite(logits).all())

    def test_init_deterministic(self, model):
        a = init_params(model, 5)
        b = init_params(model, 5)
        c = init_params(model, 6)
        for ta, tb in zip(a, b):
            np.testing.assert_array_equal(ta, tb)
        assert any(
            not np.array_equal(np.asarray(ta), np.asarray(tc)) for ta, tc in zip(a, c)
        )


class TestTraining:
    def test_grads_finite_and_nontrivial(self, model):
        params = init_params(model, 1)
        ts = jax.jit(make_train_step(model))
        x, y = batch(model)
        out = ts(params, x, y)
        loss, grads = out[0], out[1:]
        assert np.isfinite(float(loss))
        assert len(grads) == len(params)
        assert all(np.isfinite(np.asarray(g)).all() for g in grads)
        assert any(float(jnp.abs(g).max()) > 1e-8 for g in grads)

    def test_sgd_memorizes_batch(self, model):
        params = init_params(model, 2)
        ts = jax.jit(make_train_step(model))
        x, y = batch(model, bs=8, seed=3)
        first = float(ts(params, x, y)[0])
        for _ in range(25):
            out = ts(params, x, y)
            params = [p - 0.02 * g for p, g in zip(params, out[1:])]
        last = float(ts(params, x, y)[0])
        assert last < 0.7 * first, f"{first} -> {last}"


class TestEvalAndInit:
    def test_eval_counts(self, model):
        params = init_params(model, 0)
        ev = jax.jit(make_eval_step(model))
        x, y = batch(model, bs=16, seed=9)
        loss, correct = ev(params, x, y)
        assert np.isfinite(float(loss))
        assert 0 <= int(correct) <= 16

    def test_init_fn_jits(self, model):
        init = jax.jit(make_init_fn(model))
        out = init(jnp.int32(0))
        assert len(out) == len(model.net.specs)
        for t, s in zip(out, model.net.specs):
            assert t.shape == s.shape


def test_aliases_resolve():
    for alias in ALIASES:
        assert build_model(alias).name in MODEL_NAMES
    with pytest.raises(KeyError):
        build_model("resnet9000")


def test_cross_entropy_matches_manual():
    logits = jnp.asarray([[2.0, 0.0, -1.0], [0.0, 0.0, 0.0]])
    labels = jnp.asarray([0, 2], dtype=jnp.int32)
    got = float(cross_entropy(logits, labels))
    p0 = np.exp(2.0) / (np.exp(2.0) + 1 + np.exp(-1.0))
    want = float(np.mean([-np.log(p0), -np.log(1 / 3)]))
    assert abs(got - want) < 1e-5
