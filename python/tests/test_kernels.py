"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

hypothesis sweeps shapes (including tile-misaligned and singleton dims)
and dtypes; assert_allclose with dtype-scaled tolerances. This is the
CORE correctness signal for the AOT artifacts: the same kernel code is
lowered into every train_step HLO the Rust runtime executes.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    bias_add,
    bias_relu6,
    dwconv3x3,
    matmul,
    pointwise_conv,
)
from compile.kernels import ref

settings.register_profile("kernels", max_examples=25, deadline=None)
settings.load_profile("kernels")

DTYPES = [np.float32, jnp.bfloat16]


def _tol(dtype):
    return dict(rtol=3e-2, atol=3e-2) if dtype == jnp.bfloat16 else dict(rtol=1e-4, atol=1e-4)


def _rand(rng, shape, dtype):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32)).astype(dtype)


@st.composite
def matmul_shapes(draw):
    m = draw(st.integers(1, 200))
    k = draw(st.integers(1, 200))
    n = draw(st.integers(1, 200))
    return m, k, n


class TestMatmul:
    @given(shape=matmul_shapes(), dtype=st.sampled_from(DTYPES), seed=st.integers(0, 2**31 - 1))
    def test_matches_ref(self, shape, dtype, seed):
        m, k, n = shape
        rng = np.random.default_rng(seed)
        x, y = _rand(rng, (m, k), dtype), _rand(rng, (k, n), dtype)
        got = np.asarray(matmul(x, y), dtype=np.float32)
        want = np.asarray(ref.matmul(x, y), dtype=np.float32)
        np.testing.assert_allclose(got, want, **_tol(dtype))

    @pytest.mark.parametrize("m,k,n", [(128, 256, 128), (129, 257, 127), (1, 1, 1), (8, 8, 8)])
    def test_tile_boundaries(self, m, k, n):
        rng = np.random.default_rng(m * 10007 + k * 101 + n)
        x, y = _rand(rng, (m, k), np.float32), _rand(rng, (k, n), np.float32)
        np.testing.assert_allclose(matmul(x, y), ref.matmul(x, y), rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("bm,bk,bn", [(8, 8, 8), (32, 16, 64), (128, 256, 128)])
    def test_tile_size_invariance(self, bm, bk, bn):
        """Result must not depend on the chosen block decomposition."""
        rng = np.random.default_rng(42)
        x, y = _rand(rng, (100, 70), np.float32), _rand(rng, (70, 50), np.float32)
        np.testing.assert_allclose(
            matmul(x, y, bm=bm, bk=bk, bn=bn), ref.matmul(x, y), rtol=1e-4, atol=1e-4
        )

    def test_zero_inputs(self):
        x = jnp.zeros((16, 16), jnp.float32)
        assert float(jnp.abs(matmul(x, x)).max()) == 0.0

    def test_rank_check(self):
        with pytest.raises(ValueError):
            matmul(jnp.zeros((2, 2, 2)), jnp.zeros((2, 2)))

    def test_contraction_check(self):
        with pytest.raises(ValueError):
            matmul(jnp.zeros((2, 3)), jnp.zeros((4, 2)))


@st.composite
def conv_shapes(draw):
    n = draw(st.integers(1, 3))
    h = draw(st.integers(2, 20))
    w = draw(st.integers(2, 20))
    c = draw(st.integers(1, 40))
    return n, h, w, c


class TestDwConv:
    @given(
        shape=conv_shapes(),
        stride=st.sampled_from([1, 2]),
        dtype=st.sampled_from(DTYPES),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref(self, shape, stride, dtype, seed):
        rng = np.random.default_rng(seed)
        x = _rand(rng, shape, dtype)
        w = _rand(rng, (3, 3, shape[3]), dtype)
        got = np.asarray(dwconv3x3(x, w, stride=stride), dtype=np.float32)
        want = np.asarray(ref.dwconv3x3(x, w, stride=stride), dtype=np.float32)
        assert got.shape == want.shape
        np.testing.assert_allclose(got, want, **_tol(dtype))

    def test_identity_kernel(self):
        """A center-one stencil must reproduce the input exactly."""
        rng = np.random.default_rng(7)
        x = _rand(rng, (1, 8, 8, 5), np.float32)
        w = np.zeros((3, 3, 5), np.float32)
        w[1, 1, :] = 1.0
        np.testing.assert_allclose(dwconv3x3(x, jnp.asarray(w)), x, rtol=1e-6)

    def test_channel_tile_invariance(self):
        rng = np.random.default_rng(3)
        x = _rand(rng, (2, 6, 6, 50), np.float32)
        w = _rand(rng, (3, 3, 50), np.float32)
        a = dwconv3x3(x, w, bc=8)
        b = dwconv3x3(x, w, bc=128)
        np.testing.assert_allclose(a, b, rtol=1e-6)

    def test_stride_identity(self):
        """stride-2 == stride-1 then [::2, ::2] (the kernel's contract)."""
        rng = np.random.default_rng(9)
        x = _rand(rng, (1, 9, 9, 4), np.float32)
        w = _rand(rng, (3, 3, 4), np.float32)
        s1 = dwconv3x3(x, w, stride=1)
        s2 = dwconv3x3(x, w, stride=2)
        np.testing.assert_allclose(s2, s1[:, ::2, ::2, :], rtol=1e-6)

    def test_bad_stride(self):
        with pytest.raises(ValueError):
            dwconv3x3(jnp.zeros((1, 4, 4, 2)), jnp.zeros((3, 3, 2)), stride=3)

    def test_bad_weight_shape(self):
        with pytest.raises(ValueError):
            dwconv3x3(jnp.zeros((1, 4, 4, 2)), jnp.zeros((3, 3, 3)))


class TestElementwise:
    @given(shape=conv_shapes(), dtype=st.sampled_from(DTYPES), seed=st.integers(0, 2**31 - 1))
    def test_bias_relu6(self, shape, dtype, seed):
        rng = np.random.default_rng(seed)
        x = _rand(rng, shape, dtype)
        b = _rand(rng, (shape[3],), dtype)
        got = np.asarray(bias_relu6(x, b), dtype=np.float32)
        want = np.asarray(ref.bias_relu6(x, b), dtype=np.float32)
        np.testing.assert_allclose(got, want, **_tol(dtype))

    @given(rows=st.integers(1, 500), c=st.integers(1, 64), seed=st.integers(0, 2**31 - 1))
    def test_bias_add_2d(self, rows, c, seed):
        rng = np.random.default_rng(seed)
        x = _rand(rng, (rows, c), np.float32)
        b = _rand(rng, (c,), np.float32)
        np.testing.assert_allclose(bias_add(x, b), ref.bias_add(x, b), rtol=1e-6)

    def test_relu6_clamps(self):
        x = jnp.asarray([[-10.0, 0.0, 3.0, 10.0]], jnp.float32)
        b = jnp.zeros((4,), jnp.float32)
        np.testing.assert_allclose(
            np.asarray(bias_relu6(x, b))[0], [0.0, 0.0, 3.0, 6.0], rtol=1e-6
        )

    def test_bias_shape_check(self):
        with pytest.raises(ValueError):
            bias_add(jnp.zeros((4, 3)), jnp.zeros((4,)))


class TestPointwiseConv:
    @given(
        shape=conv_shapes(),
        cout=st.integers(1, 48),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref(self, shape, cout, seed):
        rng = np.random.default_rng(seed)
        x = _rand(rng, shape, np.float32)
        w = _rand(rng, (shape[3], cout), np.float32)
        np.testing.assert_allclose(
            pointwise_conv(x, w), ref.pointwise_conv(x, w), rtol=1e-4, atol=1e-4
        )
