"""AOT pipeline: lower every (network, entry-point, batch-size) to HLO text.

Interchange is HLO **text**, not a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published `xla` 0.1.6 crate links) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs, consumed by the Rust runtime (rust/src/runtime/):

  artifacts/<net>/init.hlo.txt            (seed:i32) -> (p0..pN)
  artifacts/<net>/train_bs<B>.hlo.txt     (p0..pN, x, y) -> (loss, g0..gN)
  artifacts/<net>/eval_bs<B>.hlo.txt      (p0..pN, x, y) -> (loss, correct)
  artifacts/manifest.json                 parameter order/shapes, costs,
                                          artifact paths, batch sizes

Python runs ONCE at build time (`make artifacts`); the Rust binary is
self-contained afterwards.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import pathlib
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import (
    build_model,
    example_args,
    make_eval_step,
    make_init_fn,
    make_train_step,
    spec_dicts,
)
from .models import MODEL_NAMES

# Batch sizes compiled per network. The primary network gets the full
# tuning ladder (Algorithm 1 probes these); the comparison networks get
# the subset the fig6/fig7 real-exec integration tests use.
PRIMARY = "mobilenet_v2_s"
TRAIN_BS = {
    "mobilenet_v2_s": [1, 2, 4, 8, 16, 32],
    "nasnet_s": [2, 8, 16],
    "inception_v3_s": [2, 8, 16],
    "squeezenet_s": [2, 8, 16],
}
EVAL_BS = 32


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple for rust)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(fn, *args) -> str:
    return to_hlo_text(jax.jit(fn).lower(*args))


def build_network_artifacts(name: str, out_dir: pathlib.Path, verbose: bool = True):
    model = build_model(name)
    net_dir = out_dir / name
    net_dir.mkdir(parents=True, exist_ok=True)

    entry: dict = {"train": {}, "eval": {}}

    t0 = time.time()
    init_text = lower_entry(
        make_init_fn(model), jax.ShapeDtypeStruct((), jnp.int32)
    )
    (net_dir / "init.hlo.txt").write_text(init_text)
    entry["init"] = f"{name}/init.hlo.txt"

    train_step = make_train_step(model)
    eval_step = make_eval_step(model)
    for bs in TRAIN_BS[name]:
        params, x, y = example_args(model, bs)
        text = lower_entry(lambda p, xx, yy: train_step(p, xx, yy), params, x, y)
        rel = f"{name}/train_bs{bs}.hlo.txt"
        (net_dir / f"train_bs{bs}.hlo.txt").write_text(text)
        entry["train"][str(bs)] = rel
        if verbose:
            print(f"  {rel}: {len(text) / 1e6:.2f} MB")

    params, x, y = example_args(model, EVAL_BS)
    eval_text = lower_entry(lambda p, xx, yy: eval_step(p, xx, yy), params, x, y)
    (net_dir / f"eval_bs{EVAL_BS}.hlo.txt").write_text(eval_text)
    entry["eval"][str(EVAL_BS)] = f"{name}/eval_bs{EVAL_BS}.hlo.txt"

    entry.update(
        params=spec_dicts(model),
        param_count=model.net.param_count,
        macs_per_image=model.net.macs,
        flops_per_image=model.net.flops,
        input_hw=model.input_hw,
        num_classes=model.num_classes,
        train_batch_sizes=TRAIN_BS[name],
        eval_batch_size=EVAL_BS,
    )
    if verbose:
        print(
            f"{name}: {model.net.param_count} params, "
            f"{model.net.macs / 1e6:.2f}M MACs/img ({time.time() - t0:.1f}s)"
        )
    return entry


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifacts directory")
    ap.add_argument(
        "--models", nargs="*", default=MODEL_NAMES, help="networks to lower"
    )
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    manifest = {"version": 1, "primary": PRIMARY, "networks": {}}
    for name in args.models:
        manifest["networks"][name] = build_network_artifacts(name, out_dir)

    blob = json.dumps(manifest, indent=2, sort_keys=True)
    manifest["digest"] = hashlib.sha256(blob.encode()).hexdigest()[:16]
    (out_dir / "manifest.json").write_text(
        json.dumps(manifest, indent=2, sort_keys=True)
    )
    print(f"wrote {out_dir / 'manifest.json'}")


if __name__ == "__main__":
    main()
