"""Registry of the paper's four evaluation networks, scaled for CPU.

Paper (Table I)        ->  here
  MobileNetV2 3.47M    ->  mobilenet_v2_s
  NASNet      5.3M     ->  nasnet_s
  InceptionV3 23.83M   ->  inception_v3_s
  SqueezeNet  1.25M    ->  squeezenet_s

`build_model(name)` returns a BuiltModel whose flat parameter-list order
is the AOT interchange contract with the Rust runtime (manifest.json).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List

import jax.numpy as jnp


@dataclasses.dataclass
class BuiltModel:
    name: str
    net: "Net"  # noqa: F821 — blocks.Net; kept loose to avoid import cycle
    apply: Callable[[List[jnp.ndarray], jnp.ndarray], jnp.ndarray]
    input_hw: int
    num_classes: int


# Paper-name aliases accepted by the CLI / config layer.
ALIASES = {
    "mobilenetv2": "mobilenet_v2_s",
    "mobilenet_v2": "mobilenet_v2_s",
    "nasnet": "nasnet_s",
    "inceptionv3": "inception_v3_s",
    "inception_v3": "inception_v3_s",
    "squeezenet": "squeezenet_s",
}


def build_model(name: str, **kw) -> BuiltModel:
    from . import inception_v3_s, mobilenet_v2_s, nasnet_s, squeezenet_s

    registry = {
        "mobilenet_v2_s": mobilenet_v2_s.build,
        "nasnet_s": nasnet_s.build,
        "inception_v3_s": inception_v3_s.build,
        "squeezenet_s": squeezenet_s.build,
    }
    key = ALIASES.get(name, name)
    if key not in registry:
        raise KeyError(f"unknown model {name!r}; have {sorted(registry)}")
    return registry[key](**kw)


MODEL_NAMES = ["mobilenet_v2_s", "nasnet_s", "inception_v3_s", "squeezenet_s"]
