"""Scaled-down NASNet-Mobile (Table I row 2).

Normal/reduction cells built from separable-conv pairs with additive
combinations and a cell-wide concat, mirroring NASNet's searched cell
structure — the paper's mid-size network (5.3M params, 564M MACs) with
a high MAC/param ratio, penalized by compute rather than sync.
"""

from __future__ import annotations

import jax.numpy as jnp

from . import BuiltModel
from .blocks import Net, conv3x3, fc, gap, maxpool2, out_hw, pointwise, separable


def _normal_cell(net: Net, name: str, hw: int, c: int):
    """Two combine units: (sep+sep) and (sep+id); concat then pw re-mix."""
    s1a = separable(net, f"{name}.s1a", hw, c, c)
    s1b = separable(net, f"{name}.s1b", hw, c, c)
    s2 = separable(net, f"{name}.s2", hw, c, c)
    mix = pointwise(net, f"{name}.mix", hw, 2 * c, c)

    def fwd(p, x):
        u1 = s1a(p, x) + s1b(p, x)
        u2 = s2(p, x) + x
        return mix(p, jnp.concatenate([u1, u2], axis=-1))

    return fwd


def _reduction_cell(net: Net, name: str, hw: int, cin: int, cout: int):
    """(sep stride2) + (maxpool -> pw); halves spatial, retargets channels."""
    s = separable(net, f"{name}.s", hw, cin, cout, stride=2)
    pw = pointwise(net, f"{name}.pool_pw", out_hw(hw, 2), cin, cout)

    def fwd(p, x):
        return s(p, x) + pw(p, maxpool2(x))

    return fwd


def build(num_classes: int = 64, hw: int = 32, width: float = 1.0) -> BuiltModel:
    net = Net()

    def ch(c: float) -> int:
        return max(8, int(c * width + 0.5) // 8 * 8)

    h = hw
    stem = conv3x3(net, "stem", h, 3, ch(24), stride=2)
    h = out_hw(h, 2)

    n1 = _normal_cell(net, "n1", h, ch(24))
    n2 = _normal_cell(net, "n2", h, ch(24))
    r1 = _reduction_cell(net, "r1", h, ch(24), ch(48))
    h2 = out_hw(h, 2)
    n3 = _normal_cell(net, "n3", h2, ch(48))
    n4 = _normal_cell(net, "n4", h2, ch(48))
    classifier = fc(net, "fc", ch(48), num_classes)

    def apply(p, x):
        x = stem(p, x)
        x = n2(p, n1(p, x))
        x = r1(p, x)
        x = n4(p, n3(p, x))
        return classifier(p, gap(x))

    return BuiltModel(
        name="nasnet_s",
        net=net,
        apply=apply,
        input_hw=hw,
        num_classes=num_classes,
    )
