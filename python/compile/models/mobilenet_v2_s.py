"""Scaled-down MobileNetV2 — the paper's primary network (Table I row 1).

Same topology family as the paper's 3.47M-param/56M-MAC MobileNetV2
(inverted residual bottlenecks, ReLU6, linear projections, final 1x1
head + GAP + FC) at a width/depth that trains at CPU-interpret speed.
"""

from __future__ import annotations

from . import BuiltModel
from .blocks import Net, conv3x3, fc, gap, inverted_residual, out_hw, pointwise


def build(num_classes: int = 64, hw: int = 32, width: float = 1.0) -> BuiltModel:
    net = Net()

    def ch(c: float) -> int:
        return max(8, int(c * width + 0.5) // 8 * 8)

    layers = []
    h = hw
    stem = conv3x3(net, "stem", h, 3, ch(16), stride=2)
    h = out_hw(h, 2)
    layers.append(stem)

    # (cin, cout, stride, expand) — a compressed MobileNetV2 schedule.
    cfg = [
        (ch(16), ch(16), 1, 1),
        (ch(16), ch(24), 2, 4),
        (ch(24), ch(24), 1, 4),
        (ch(24), ch(32), 2, 4),
        (ch(32), ch(32), 1, 4),
        (ch(32), ch(32), 1, 4),
    ]
    for i, (cin, cout, s, e) in enumerate(cfg):
        layers.append(inverted_residual(net, f"ir{i}", h, cin, cout, s, e))
        h = out_hw(h, s)

    head_c = ch(128)
    layers.append(pointwise(net, "head", h, cfg[-1][1], head_c))
    classifier = fc(net, "fc", head_c, num_classes)

    def apply(p, x):
        for layer in layers:
            x = layer(p, x)
        return classifier(p, gap(x))

    return BuiltModel(
        name="mobilenet_v2_s",
        net=net,
        apply=apply,
        input_hw=hw,
        num_classes=num_classes,
    )
