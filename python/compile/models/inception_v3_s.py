"""Scaled-down InceptionV3 (Table I row 3).

Multi-branch inception blocks (1x1 / 1x1->3x3 / dw-pool->1x1) with
channel concat — the paper's *largest* network (23.8M params), which
Fig. 7 shows scaling worst because parameter-sync time dominates.
"""

from __future__ import annotations

import jax.numpy as jnp

from . import BuiltModel
from .blocks import Net, conv3x3, dwconv, fc, gap, maxpool2, out_hw, pointwise


def _inception(net: Net, name: str, hw: int, cin: int, b1: int, b3: int, bp: int):
    """Branches: pw(b1) | pw(b3/2)->3x3(b3) | dw3x3->pw(bp); concat."""
    br1 = pointwise(net, f"{name}.b1", hw, cin, b1)
    br3a = pointwise(net, f"{name}.b3a", hw, cin, max(8, b3 // 2))
    br3b = conv3x3(net, f"{name}.b3b", hw, max(8, b3 // 2), b3)
    brpa = dwconv(net, f"{name}.bpa", hw, cin)
    brpb = pointwise(net, f"{name}.bpb", hw, cin, bp)

    def fwd(p, x):
        return jnp.concatenate(
            [br1(p, x), br3b(p, br3a(p, x)), brpb(p, brpa(p, x))], axis=-1
        )

    return fwd, b1 + b3 + bp


def build(num_classes: int = 64, hw: int = 32, width: float = 1.0) -> BuiltModel:
    net = Net()

    def ch(c: float) -> int:
        return max(8, int(c * width + 0.5) // 8 * 8)

    h = hw
    stem = conv3x3(net, "stem", h, 3, ch(24), stride=2)
    h = out_hw(h, 2)

    inc1, c1 = _inception(net, "inc1", h, ch(24), ch(16), ch(16), ch(16))
    inc2, c2 = _inception(net, "inc2", h, c1, ch(24), ch(24), ch(16))
    red = conv3x3(net, "reduce", h, c2, ch(64), stride=2)
    h2 = out_hw(h, 2)
    inc3, c3 = _inception(net, "inc3", h2, ch(64), ch(32), ch(32), ch(24))
    inc4, c4 = _inception(net, "inc4", h2, c3, ch(48), ch(48), ch(32))
    classifier = fc(net, "fc", c4, num_classes)

    def apply(p, x):
        x = stem(p, x)
        x = inc2(p, inc1(p, x))
        x = red(p, x)
        x = inc4(p, inc3(p, x))
        return classifier(p, gap(x))

    return BuiltModel(
        name="inception_v3_s",
        net=net,
        apply=apply,
        input_hw=hw,
        num_classes=num_classes,
    )
