"""Scaled-down SqueezeNet (Table I row 4).

Fire modules (pointwise squeeze -> parallel 1x1/3x3 expand, concat),
ending in a 1x1 class conv + GAP as in the original — the paper's
smallest-parameter / highest-MAC-density network, which Fig. 7 shows
scaling *worse* than MobileNetV2 despite fewer parameters.
"""

from __future__ import annotations

import jax.numpy as jnp

from . import BuiltModel
from .blocks import Net, conv3x3, maxpool2, out_hw, pointwise


def _fire(net: Net, name: str, hw: int, cin: int, squeeze: int, expand: int):
    sq = pointwise(net, f"{name}.squeeze", hw, cin, squeeze)
    e1 = pointwise(net, f"{name}.e1", hw, squeeze, expand)
    e3 = conv3x3(net, f"{name}.e3", hw, squeeze, expand)

    def fwd(p, x):
        s = sq(p, x)
        return jnp.concatenate([e1(p, s), e3(p, s)], axis=-1)

    return fwd, 2 * expand


def build(num_classes: int = 64, hw: int = 32, width: float = 1.0) -> BuiltModel:
    net = Net()

    def ch(c: float) -> int:
        return max(8, int(c * width + 0.5) // 8 * 8)

    h = hw
    stem = conv3x3(net, "stem", h, 3, ch(32), stride=2)
    h = out_hw(h, 2)

    fire1, c1 = _fire(net, "fire1", h // 2, ch(32), ch(8), ch(32))
    fire2, c2 = _fire(net, "fire2", h // 2, c1, ch(8), ch(32))
    fire3, c3 = _fire(net, "fire3", h // 4, c2, ch(16), ch(48))
    fire4, c4 = _fire(net, "fire4", h // 4, c3, ch(16), ch(48))
    class_conv = pointwise(net, "class_conv", h // 4, c4, num_classes, act=False)

    def apply(p, x):
        x = stem(p, x)
        x = maxpool2(x)
        x = fire2(p, fire1(p, x))
        x = maxpool2(x)
        x = fire4(p, fire3(p, x))
        x = class_conv(p, x)
        return jnp.mean(x, axis=(1, 2))  # GAP straight to logits

    return BuiltModel(
        name="squeezenet_s",
        net=net,
        apply=apply,
        input_hw=hw,
        num_classes=num_classes,
    )
