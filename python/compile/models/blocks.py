"""Shared building blocks for the scaled-down paper networks.

A tiny functional "net builder": layers register parameter specs on a
`Net` while closing over their parameter indices, so `apply` consumes a
flat parameter *list* in exactly the declaration order. That order is
the AOT contract — `aot.py` writes it into `artifacts/manifest.json` and
the Rust runtime feeds PJRT arguments in the same order.

All dense compute routes through the L1 Pallas kernels (matmul /
dwconv3x3 / bias_{add,relu6}); only shape plumbing (pad/reshape/pool)
uses raw jnp. FLOP/MAC counters are accumulated at build time from the
static shapes, giving the analytic per-image costs Table I reports.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, List, Sequence, Tuple

import jax.numpy as jnp

from .. import kernels


@dataclasses.dataclass
class ParamSpec:
    name: str
    shape: Tuple[int, ...]
    init: str  # "he" | "zero" | "fc"

    @property
    def size(self) -> int:
        return int(math.prod(self.shape))


class Net:
    """Accumulates parameter specs + per-image FLOP/MAC counts."""

    def __init__(self) -> None:
        self.specs: List[ParamSpec] = []
        self.flops: int = 0  # multiply-adds counted as 2 flops
        self.macs: int = 0

    def param(self, name: str, shape: Sequence[int], init: str = "he") -> int:
        for s in self.specs:
            if s.name == name:
                raise ValueError(f"duplicate param name {name!r}")
        self.specs.append(ParamSpec(name, tuple(int(d) for d in shape), init))
        return len(self.specs) - 1

    def add_mac(self, macs: int) -> None:
        self.macs += int(macs)
        self.flops += 2 * int(macs)

    @property
    def param_count(self) -> int:
        return sum(s.size for s in self.specs)


# A layer forward: (params_list, activations) -> activations
Fwd = Callable[[List[jnp.ndarray], jnp.ndarray], jnp.ndarray]


def out_hw(h: int, stride: int) -> int:
    """Spatial size after a 3x3/pad-1 conv with `stride` (see dwconv)."""
    return (h - 1) // stride + 1


def pointwise(net: Net, name: str, hw: int, cin: int, cout: int, act: bool = True) -> Fwd:
    """1x1 conv + bias (+ ReLU6) via the Pallas matmul kernel."""
    wi = net.param(f"{name}.w", (cin, cout))
    bi = net.param(f"{name}.b", (cout,), init="zero")
    net.add_mac(hw * hw * cin * cout)

    def fwd(p, x):
        y = kernels.pointwise_conv(x, p[wi])
        return kernels.bias_relu6(y, p[bi]) if act else kernels.bias_add(y, p[bi])

    return fwd


def dwconv(net: Net, name: str, hw: int, c: int, stride: int = 1, act: bool = True) -> Fwd:
    """Depthwise 3x3 + bias (+ ReLU6) via the Pallas stencil kernel."""
    wi = net.param(f"{name}.w", (3, 3, c))
    bi = net.param(f"{name}.b", (c,), init="zero")
    net.add_mac(out_hw(hw, stride) ** 2 * 9 * c)

    def fwd(p, x):
        y = kernels.dwconv3x3(x, p[wi], stride=stride)
        return kernels.bias_relu6(y, p[bi]) if act else kernels.bias_add(y, p[bi])

    return fwd


def conv3x3(net: Net, name: str, hw: int, cin: int, cout: int, stride: int = 1, act: bool = True) -> Fwd:
    """Dense 3x3 conv as nine shifted pointwise matmuls (all Pallas).

    conv3x3(x, W)[n, i, j, :] = sum_{dh,dw} x_pad[n, i*s+dh, j*s+dw, :] @ W[dh, dw]
    which we evaluate as nine (n*h*w, cin) @ (cin, cout) matmuls over the
    shifted (stride-subsampled) input — dense conv on the MXU without an
    im2col buffer 9x the activation size.
    """
    wis = [net.param(f"{name}.w{dh}{dw}", (cin, cout)) for dh in range(3) for dw in range(3)]
    bi = net.param(f"{name}.b", (cout,), init="zero")
    ho = out_hw(hw, stride)
    net.add_mac(ho * ho * 9 * cin * cout)

    def fwd(p, x):
        n, h, w, _ = x.shape
        hp = out_hw(h, stride)
        wp = out_hw(w, stride)
        xp = jnp.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0)))
        acc = None
        for dh in range(3):
            for dw in range(3):
                shift = xp[:, dh : dh + h : stride, dw : dw + w : stride, :]
                term = kernels.pointwise_conv(shift, p[wis[dh * 3 + dw]])
                acc = term if acc is None else acc + term
        assert acc.shape[1:3] == (hp, wp), (acc.shape, hp, wp)
        return kernels.bias_relu6(acc, p[bi]) if act else kernels.bias_add(acc, p[bi])

    return fwd


def separable(net: Net, name: str, hw: int, cin: int, cout: int, stride: int = 1) -> Fwd:
    """Depthwise-separable conv: dw3x3 (+relu6) then pw projection (+relu6)."""
    dw = dwconv(net, f"{name}.dw", hw, cin, stride=stride)
    pw = pointwise(net, f"{name}.pw", out_hw(hw, stride), cin, cout)

    def fwd(p, x):
        return pw(p, dw(p, x))

    return fwd


def inverted_residual(net: Net, name: str, hw: int, cin: int, cout: int, stride: int, expand: int) -> Fwd:
    """MobileNetV2 inverted residual: pw-expand, dw3x3, linear pw-project."""
    mid = cin * expand
    ex = pointwise(net, f"{name}.expand", hw, cin, mid) if expand != 1 else None
    dw = dwconv(net, f"{name}.dw", hw, mid, stride=stride)
    pj = pointwise(net, f"{name}.project", out_hw(hw, stride), mid, cout, act=False)
    has_res = stride == 1 and cin == cout

    def fwd(p, x):
        y = ex(p, x) if ex is not None else x
        y = pj(p, dw(p, y))
        return x + y if has_res else y

    return fwd


def fc(net: Net, name: str, cin: int, cout: int) -> Fwd:
    """Final classifier: (n, cin) @ (cin, cout) + bias."""
    wi = net.param(f"{name}.w", (cin, cout), init="fc")
    bi = net.param(f"{name}.b", (cout,), init="zero")
    net.add_mac(cin * cout)

    def fwd(p, x):
        return kernels.bias_add(kernels.matmul(x, p[wi]), p[bi])

    return fwd


def gap(x: jnp.ndarray) -> jnp.ndarray:
    """Global average pool (n, h, w, c) -> (n, c)."""
    return jnp.mean(x, axis=(1, 2))


def maxpool2(x: jnp.ndarray) -> jnp.ndarray:
    """2x2 max pool, stride 2 (pads odd spatial dims)."""
    n, h, w, c = x.shape
    if h % 2 or w % 2:
        x = jnp.pad(
            x,
            ((0, 0), (0, h % 2), (0, w % 2), (0, 0)),
            constant_values=-jnp.inf,
        )
        h, w = x.shape[1], x.shape[2]
    x = x.reshape(n, h // 2, 2, w // 2, 2, c)
    return x.max(axis=(2, 4))


def avgpool2(x: jnp.ndarray) -> jnp.ndarray:
    """2x2 average pool, stride 2 (h, w assumed even)."""
    n, h, w, c = x.shape
    x = x.reshape(n, h // 2, 2, w // 2, 2, c)
    return x.mean(axis=(2, 4))
