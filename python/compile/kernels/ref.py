"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness).

Every kernel in this package has an exact (up to float accumulation
order) counterpart here; `python/tests/test_kernels.py` sweeps shapes and
dtypes with hypothesis and asserts allclose between the two.

Convention notes:
  * All convolutions use NHWC activations and explicit padding (no
    "SAME"/"VALID" strings) so the Pallas and jnp paths share one
    unambiguous spatial contract.
  * Depthwise convolution weights are (kh, kw, c); pointwise (1x1) conv
    is expressed as a matmul over a (n*h*w, cin) reshape.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def matmul(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """(m, k) @ (k, n) -> (m, n), f32 accumulation."""
    return jnp.matmul(x, y, preferred_element_type=jnp.float32).astype(x.dtype)


def bias_relu6(x: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """x + b (broadcast over last dim) followed by ReLU6 clamp."""
    return jnp.clip(x + b, 0.0, 6.0)


def bias_add(x: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return x + b


def dwconv3x3(x: jnp.ndarray, w: jnp.ndarray, stride: int = 1) -> jnp.ndarray:
    """Depthwise 3x3 convolution, NHWC, explicit pad=1 on both sides.

    x: (n, h, w, c), w: (3, 3, c). With pad=1/k=3 the output spatial
    size is floor((h - 1) / stride) + 1, matching the
    pad-then-subsample identity the Pallas kernel relies on.
    """
    n, h, wd, c = x.shape
    out = lax.conv_general_dilated(
        x,
        w.reshape(3, 3, 1, c),
        window_strides=(stride, stride),
        padding=((1, 1), (1, 1)),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=c,
    )
    return out.astype(x.dtype)


def pointwise_conv(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """1x1 convolution as matmul. x: (n, h, w, cin), w: (cin, cout)."""
    n, h, wd, cin = x.shape
    flat = x.reshape(n * h * wd, cin)
    out = matmul(flat, w)
    return out.reshape(n, h, wd, w.shape[1])


def global_avg_pool(x: jnp.ndarray) -> jnp.ndarray:
    """(n, h, w, c) -> (n, c)."""
    return jnp.mean(x, axis=(1, 2))
