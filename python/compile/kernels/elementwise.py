"""Fused bias + activation Pallas kernels.

Fusing the bias add and ReLU6 clamp into one VMEM pass avoids a second
HBM round-trip after every conv — the same fusion the paper gets for
free from TensorFlow's CPU graph optimizer on the A53, expressed here as
an explicit kernel so it survives AOT lowering verbatim.

Autodiff: custom VJPs. The ReLU6 mask is recomputed from the saved
pre-activation (strictly-inside-(0,6) subgradient); bias gradients are
row reductions in jnp.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BR = 256  # row tile


def _bias_relu6_kernel(x_ref, b_ref, o_ref):
    o_ref[...] = jnp.clip(x_ref[...] + b_ref[...], 0.0, 6.0)


def _bias_add_kernel(x_ref, b_ref, o_ref):
    o_ref[...] = x_ref[...] + b_ref[...]


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _run_rowwise(kernel, x: jnp.ndarray, b: jnp.ndarray, br: int) -> jnp.ndarray:
    """Apply a (rows, c)-blocked kernel to x of any rank with trailing dim c."""
    orig_shape = x.shape
    c = orig_shape[-1]
    rows = 1
    for d in orig_shape[:-1]:
        rows *= d
    flat = x.reshape(rows, c)

    br = min(br, _ceil_to(rows, 8))
    rp = _ceil_to(rows, br)
    xp = jnp.pad(flat, ((0, rp - rows), (0, 0)))

    out = pl.pallas_call(
        kernel,
        grid=(rp // br,),
        in_specs=[
            pl.BlockSpec((br, c), lambda ri: (ri, 0)),
            pl.BlockSpec((c,), lambda ri: (0,)),
        ],
        out_specs=pl.BlockSpec((br, c), lambda ri: (ri, 0)),
        out_shape=jax.ShapeDtypeStruct((rp, c), x.dtype),
        interpret=True,
    )(xp, b)
    return out[:rows].reshape(orig_shape)


def _reduce_to_bias(g: jnp.ndarray) -> jnp.ndarray:
    return jnp.sum(g, axis=tuple(range(g.ndim - 1)))


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _bias_relu6_vjp(x, b, br):
    return _run_rowwise(_bias_relu6_kernel, x, b, br)


def _bias_relu6_fwd(x, b, br):
    return _run_rowwise(_bias_relu6_kernel, x, b, br), (x, b)


def _bias_relu6_bwd(br, res, g):
    x, b = res
    pre = x + b
    mask = ((pre > 0.0) & (pre < 6.0)).astype(g.dtype)
    gx = g * mask
    return gx, _reduce_to_bias(gx)


_bias_relu6_vjp.defvjp(_bias_relu6_fwd, _bias_relu6_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _bias_add_vjp(x, b, br):
    return _run_rowwise(_bias_add_kernel, x, b, br)


def _bias_add_fwd(x, b, br):
    return _run_rowwise(_bias_add_kernel, x, b, br), None


def _bias_add_bwd(br, _res, g):
    return g, _reduce_to_bias(g)


_bias_add_vjp.defvjp(_bias_add_fwd, _bias_add_bwd)


def _check(x, b):
    if b.shape != (x.shape[-1],):
        raise ValueError(f"bias shape {b.shape} != ({x.shape[-1]},)")


def bias_relu6(x: jnp.ndarray, b: jnp.ndarray, *, br: int = DEFAULT_BR) -> jnp.ndarray:
    """clip(x + b, 0, 6) with bias broadcast over the last dim."""
    _check(x, b)
    return _bias_relu6_vjp(x, b, br)


def bias_add(x: jnp.ndarray, b: jnp.ndarray, *, br: int = DEFAULT_BR) -> jnp.ndarray:
    """x + b with bias broadcast over the last dim."""
    _check(x, b)
    return _bias_add_vjp(x, b, br)
