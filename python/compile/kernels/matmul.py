"""Tiled matmul Pallas kernel — the MXU path for pointwise (1x1) convs.

This is the hw-codesign adaptation of the paper's hot-spot (see
DESIGN.md §Hardware-Adaptation): MobileNetV2's MAC budget is dominated by
1x1 convolutions, which we express as a (m, k) @ (k, n) matmul over the
NHWC pixel-major reshape. The BlockSpec streams (bm, bk) / (bk, bn)
blocks HBM->VMEM and accumulates over the k grid axis — the role
threadblock shared-memory tiling plays on GPU and loop blocking plays on
the A53's L1 cache in the paper's own deployment.

Autodiff: pallas_call with a program_id accumulator has no JVP rule, so
`matmul` carries a custom VJP whose backward pass is two more calls of
the *same* Pallas kernel (dx = g yᵀ, dy = xᵀ g) — the training hot loop
stays on the kernel in both directions.

interpret=True always: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel lowers to plain HLO (see /opt/xla-example).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-shaped default tiles (128x128 systolic array); bk sized so one
# (bm, bk) + (bk, bn) + (bm, bn) working set stays well under VMEM
# (3 * 128*256 * 4B = 384 KiB << 16 MiB).
DEFAULT_BM = 128
DEFAULT_BK = 256
DEFAULT_BN = 128


def _matmul_kernel(x_ref, y_ref, o_ref):
    """Grid (mi, ni, ki); accumulates partial products into o_ref."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn"))
def _matmul_impl(x, y, bm: int, bk: int, bn: int):
    m, k = x.shape
    _, n = y.shape

    # Shrink tiles for small problems so the grid is never empty work.
    bm = min(bm, _ceil_to(m, 8))
    bk = min(bk, _ceil_to(k, 8))
    bn = min(bn, _ceil_to(n, 8))

    mp, kp, np_ = _ceil_to(m, bm), _ceil_to(k, bk), _ceil_to(n, bn)
    xp = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    yp = jnp.pad(y, ((0, kp - k), (0, np_ - n)))

    out = pl.pallas_call(
        _matmul_kernel,
        grid=(mp // bm, np_ // bn, kp // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda mi, ni, ki: (mi, ki)),
            pl.BlockSpec((bk, bn), lambda mi, ni, ki: (ki, ni)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda mi, ni, ki: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        interpret=True,
    )(xp, yp)
    return out[:m, :n]


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def _matmul_vjp(x, y, bm, bk, bn):
    return _matmul_impl(x, y, bm, bk, bn)


def _matmul_fwd(x, y, bm, bk, bn):
    return _matmul_impl(x, y, bm, bk, bn), (x, y)


def _matmul_bwd(bm, bk, bn, res, g):
    x, y = res
    # dx = g @ yᵀ  (m,n)@(n,k); dy = xᵀ @ g  (k,m)@(m,n) — same kernel.
    dx = _matmul_impl(g, y.T, bm, bk, bn)
    dy = _matmul_impl(x.T, g, bm, bk, bn)
    return dx, dy


_matmul_vjp.defvjp(_matmul_fwd, _matmul_bwd)


def matmul(
    x: jnp.ndarray,
    y: jnp.ndarray,
    *,
    bm: int = DEFAULT_BM,
    bk: int = DEFAULT_BK,
    bn: int = DEFAULT_BN,
) -> jnp.ndarray:
    """(m, k) @ (k, n) -> (m, n) via the Pallas tiled kernel.

    Shapes need not divide the tile sizes; inputs are zero-padded up to
    the tile lattice and the result sliced back (exact for matmul).
    Differentiable via the custom VJP above.
    """
    if x.ndim != 2 or y.ndim != 2:
        raise ValueError(f"matmul expects rank-2 operands, got {x.shape} @ {y.shape}")
    if x.shape[1] != y.shape[0]:
        raise ValueError(f"contraction mismatch: {x.shape} @ {y.shape}")
    return _matmul_vjp(x, y, bm, bk, bn)


def pointwise_conv(x: jnp.ndarray, w: jnp.ndarray, **tile_kw) -> jnp.ndarray:
    """1x1 convolution: (n, h, w, cin) x (cin, cout) -> (n, h, w, cout)."""
    n, h, wd, cin = x.shape
    out = matmul(x.reshape(n * h * wd, cin), w, **tile_kw)
    return out.reshape(n, h, wd, w.shape[1])
