"""L1 — Pallas kernels for the training hot-spot (see DESIGN.md §3).

All kernels run with interpret=True so they lower to plain HLO the CPU
PJRT client can execute; real-TPU perf is estimated from the BlockSpecs
in DESIGN.md/EXPERIMENTS.md §Perf.
"""

from .dwconv import dwconv3x3
from .elementwise import bias_add, bias_relu6
from .matmul import matmul, pointwise_conv

__all__ = ["matmul", "pointwise_conv", "dwconv3x3", "bias_add", "bias_relu6"]
