"""Depthwise 3x3 convolution Pallas kernel.

The depthwise stage of MobileNetV2's inverted residual is memory-bound
(9 MACs per element); on TPU the win is streaming: the BlockSpec moves
one (batch row, full spatial extent, channel tile) block HBM->VMEM per
grid step and the kernel does the whole 3x3 stencil out of VMEM as nine
shifted multiply-adds — channel-vectorized on the VPU lanes, no im2col
materialization.

Stride-2 is implemented by the pad-then-subsample identity: a stride-1
3x3 conv with explicit pad=1 followed by `out[::2, ::2]` equals the
stride-2 conv with the same padding (the kernel computes stride-1; the
wrapper subsamples). This keeps a single kernel for both strides.

Autodiff: custom VJP. dx is the *same* Pallas kernel applied to the
(dilated, for stride 2) cotangent with the spatially-flipped weights —
the transpose of a pad-1 3x3 stencil is a pad-1 3x3 stencil. dw is a
nine-term reduction done in jnp (it is 9·c scalars; never a hot spot).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BC = 128  # channel tile: one VPU lane group


def _dwconv_kernel(x_ref, w_ref, o_ref):
    """x: (1, h+2, w+2, bc) pre-padded, w: (3, 3, bc), o: (1, h, w, bc)."""
    _, hp, wp, _ = x_ref.shape
    h, w = hp - 2, wp - 2
    acc = jnp.zeros(o_ref.shape, dtype=jnp.float32)
    for dh in range(3):
        for dw in range(3):
            acc += (
                x_ref[:, dh : dh + h, dw : dw + w, :].astype(jnp.float32)
                * w_ref[dh, dw, :]
            )
    o_ref[...] = acc.astype(o_ref.dtype)


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@functools.partial(jax.jit, static_argnames=("bc",))
def _dwconv_s1(x, w, bc: int):
    """Stride-1 pad-1 depthwise 3x3 via the Pallas kernel."""
    n, h, wd, c = x.shape
    bc = min(bc, _ceil_to(c, 8))
    cp = _ceil_to(c, bc)
    # Spatial halo pad (the stencil's pad=1) + channel pad to the tile.
    xp = jnp.pad(x, ((0, 0), (1, 1), (1, 1), (0, cp - c)))
    wp = jnp.pad(w, ((0, 0), (0, 0), (0, cp - c)))

    out = pl.pallas_call(
        _dwconv_kernel,
        grid=(n, cp // bc),
        in_specs=[
            pl.BlockSpec((1, h + 2, wd + 2, bc), lambda ni, ci: (ni, 0, 0, ci)),
            pl.BlockSpec((3, 3, bc), lambda ni, ci: (0, 0, ci)),
        ],
        out_specs=pl.BlockSpec((1, h, wd, bc), lambda ni, ci: (ni, 0, 0, ci)),
        out_shape=jax.ShapeDtypeStruct((n, h, wd, cp), x.dtype),
        interpret=True,
    )(xp, wp)
    return out[..., :c]


def _dwconv_impl(x, w, stride: int, bc: int):
    out = _dwconv_s1(x, w, bc)
    if stride == 2:
        out = out[:, ::2, ::2, :]
    return out


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _dwconv_vjp(x, w, stride, bc):
    return _dwconv_impl(x, w, stride, bc)


def _dwconv_fwd(x, w, stride, bc):
    return _dwconv_impl(x, w, stride, bc), (x, w)


def _dwconv_bwd(stride, bc, res, g):
    x, w = res
    n, h, wd, c = x.shape
    if stride == 2:
        # Scatter the cotangent back onto the stride-1 lattice.
        gs = jnp.zeros((n, h, wd, c), g.dtype).at[:, ::2, ::2, :].set(g)
    else:
        gs = g
    # dx: transpose of a pad-1 stencil = pad-1 stencil with flipped taps.
    dx = _dwconv_s1(gs, w[::-1, ::-1, :], bc)
    # dw[dh, dwi, c] = sum_{n,i,j} x_pad[n, i+dh, j+dwi, c] * gs[n, i, j, c]
    xp = jnp.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0)))
    taps = [
        jnp.sum(xp[:, dh : dh + h, dwi : dwi + wd, :] * gs, axis=(0, 1, 2))
        for dh in range(3)
        for dwi in range(3)
    ]
    dw = jnp.stack(taps).reshape(3, 3, c)
    return dx, dw


_dwconv_vjp.defvjp(_dwconv_fwd, _dwconv_bwd)


def dwconv3x3(
    x: jnp.ndarray, w: jnp.ndarray, stride: int = 1, *, bc: int = DEFAULT_BC
) -> jnp.ndarray:
    """Depthwise 3x3 conv, NHWC, explicit pad=1. x: (n,h,w,c), w: (3,3,c)."""
    if stride not in (1, 2):
        raise ValueError(f"stride must be 1 or 2, got {stride}")
    if x.ndim != 4 or w.shape != (3, 3, x.shape[3]):
        raise ValueError(f"weight shape {w.shape} incompatible with input {x.shape}")
    return _dwconv_vjp(x, w, stride, bc)
