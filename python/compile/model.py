"""L2 — training-step definitions lowered to the AOT artifacts.

Three jittable entry points per network, each a pure function over a
flat parameter list (order = manifest order = Rust PJRT argument order):

  init_fn(seed)                -> (p0, ..., pN)
  train_step(p..., x, y)       -> (loss, g0, ..., gN)
  eval_step(p..., x, y)        -> (loss, correct_count)

The SGD update itself happens in Rust *after* ring-allreduce of the
gradients (DESIGN.md §6), so the artifact returns raw gradients — that
is what makes the Rust allreduce a real reduction rather than a replay.
"""

from __future__ import annotations

import math
from typing import Callable, List, Tuple

import jax
import jax.numpy as jnp

from .models import BuiltModel, build_model  # noqa: F401  (re-export)


def init_params(model: BuiltModel, seed) -> List[jnp.ndarray]:
    """He-normal weights / zero biases, one fold per parameter index."""
    key = jax.random.PRNGKey(seed)
    params = []
    for i, spec in enumerate(model.net.specs):
        k = jax.random.fold_in(key, i)
        if spec.init == "zero":
            params.append(jnp.zeros(spec.shape, jnp.float32))
        elif spec.init == "fc":
            fan_in = spec.shape[0]
            std = 1.0 / math.sqrt(fan_in)
            params.append(std * jax.random.normal(k, spec.shape, jnp.float32))
        else:  # "he"
            fan_in = int(math.prod(spec.shape[:-1]))
            std = math.sqrt(2.0 / max(1, fan_in))
            params.append(std * jax.random.normal(k, spec.shape, jnp.float32))
    return params


def make_init_fn(model: BuiltModel) -> Callable:
    def init_fn(seed: jnp.ndarray) -> Tuple[jnp.ndarray, ...]:
        return tuple(init_params(model, seed))

    return init_fn


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean softmax cross-entropy with integer labels."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - picked)


def make_train_step(model: BuiltModel) -> Callable:
    def loss_fn(params: List[jnp.ndarray], x: jnp.ndarray, y: jnp.ndarray):
        return cross_entropy(model.apply(params, x), y)

    def train_step(params, x, y):
        loss, grads = jax.value_and_grad(loss_fn)(list(params), x, y)
        return (loss, *grads)

    return train_step


def make_eval_step(model: BuiltModel) -> Callable:
    def eval_step(params, x, y):
        logits = model.apply(list(params), x)
        loss = cross_entropy(logits, y)
        correct = jnp.sum((jnp.argmax(logits, axis=-1) == y).astype(jnp.int32))
        return loss, correct

    return eval_step


def example_args(model: BuiltModel, batch_size: int):
    """ShapeDtypeStructs for lowering train/eval at a given batch size."""
    params = tuple(
        jax.ShapeDtypeStruct(s.shape, jnp.float32) for s in model.net.specs
    )
    x = jax.ShapeDtypeStruct(
        (batch_size, model.input_hw, model.input_hw, 3), jnp.float32
    )
    y = jax.ShapeDtypeStruct((batch_size,), jnp.int32)
    return params, x, y


def spec_dicts(model: BuiltModel) -> List[dict]:
    return [
        {"name": s.name, "shape": list(s.shape), "dtype": "f32", "init": s.init}
        for s in model.net.specs
    ]
